"""Quickstart: LLM-QFL in ~20 lines.

  PYTHONPATH=src python examples/quickstart.py

Builds the genomic federated task (3 quantum devices), fine-tunes each
device's LLM once, then runs 4 regulated federated rounds and prints the
controller's decisions.
"""
from repro.core import run_experiment
from repro.data.tasks import build_task

task = build_task("genomic", n_clients=3, train_size=150,
                  test_size=60, val_size=40, seed=0)

result = run_experiment(
    task,
    method="llm-qfl",       # "qfl" = the paper's FedAvg baseline
    n_rounds=4,
    maxiter0=8,             # COBYLA-style per-round iteration budget
    llm_steps=20,           # round-1 LoRA fine-tuning steps
    select_frac=1.0,        # aggregate all devices (try 0.34)
)

print(f"LLM reference losses: {[round(l, 3) for l in result.llm_losses]}")
for r in result.rounds:
    print(f"round {r.t}: maxiters={r.maxiters} "
          f"server_loss={r.server_loss:.4f} "
          f"test_acc={r.server_test_acc:.3f}")
print("early stop:", result.terminated_early)
