"""End-to-end driver — paper Experiment I (genomic, VQC, LLaMA-family LLM).

Reproduces the full pipeline on the noisy AerSim backend with non-IID
(Dirichlet 0.5) client data, comparing QFL vs LLM-QFL-all vs
LLM-QFL-selected, and writes per-round histories to
experiments/runs/exp1_*/.

  PYTHONPATH=src python examples/federated_genomic.py [--rounds 8]
"""
import argparse

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--clients", type=int, default=5)
    args = ap.parse_args()

    common = ["--task", "genomic", "--backend", "aersim",
              "--rounds", str(args.rounds), "--clients", str(args.clients),
              "--non-iid-alpha", "0.5", "--no-early-stop"]
    print("=" * 60, "\nQFL (FedAvg baseline)\n", "=" * 60)
    train.main(["--method", "qfl", *common,
                "--out", "experiments/runs/exp1_qfl"])
    print("=" * 60, "\nLLM-QFL (all devices)\n", "=" * 60)
    train.main(["--method", "llm-qfl", *common,
                "--out", "experiments/runs/exp1_llmqfl_all"])
    print("=" * 60, "\nLLM-QFL (selected 20%)\n", "=" * 60)
    train.main(["--method", "llm-qfl", "--select-frac", "0.2", *common,
                "--out", "experiments/runs/exp1_llmqfl_sel"])


if __name__ == "__main__":
    main()
