"""Serving example: batched autoregressive decode on the model substrate.

Loads a reduced same-family config (--arch any assigned id), prefillss a
batch of token prompts, then decodes N tokens per request through
``serve_step`` with the KV/state cache — the same code path the
decode_32k / long_500k dry-runs lower at production shapes.

  PYTHONPATH=src python examples/serving.py --arch jamba-1.5-large-398b \
      --batch 4 --steps 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get(args.arch + "-smoke")
    print(f"serving {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"pattern={[m for m, _ in cfg.pattern]}")
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    adapters = M.init_adapters(cfg, key, params)

    B, P, S = args.batch, args.prompt_len, args.prompt_len + args.steps
    prompts = jax.random.randint(key, (B, P), 4, cfg.vocab_size - 4)

    # prefill: cache created by running the prompt through decode steps
    # (smoke-scale; production prefill uses make_prefill_step + dry-run)
    cache = M.init_cache(cfg, B, S)
    serve = jax.jit(M.make_serve_step(cfg))

    t0 = time.time()
    tok = prompts[:, :1]
    for p in range(P):
        logits, cache = serve(params, adapters, cache, prompts[:, p:p + 1],
                              jnp.asarray(p))
    print(f"prefill({P} tokens, sequential smoke path): "
          f"{time.time()-t0:.2f}s")

    t0 = time.time()
    out = []
    tok = jnp.argmax(logits, -1)[:, None]
    for s in range(args.steps):
        key, k = jax.random.split(key)
        logits, cache = serve(params, adapters, cache, tok,
                              jnp.asarray(P + s))
        tok = jax.random.categorical(k, logits / args.temperature)[:, None]
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, 1)
    print(f"decoded {args.steps} tokens × {B} requests in {dt:.2f}s "
          f"({B*args.steps/dt:.1f} tok/s)")
    print("sample token ids:", gen[0, :12].tolist())


if __name__ == "__main__":
    main()
