"""LLM-as-controller demo: watch the three control laws fire.

Shows, per round: the regulation law rescaling each device's maxiter from
the loss ratio (4 App.-F variants side by side on device 0), the
alignment-based selection decision, and the early-termination check.

  PYTHONPATH=src python examples/controller_demo.py
"""
import numpy as np

from repro.core import regulation, selection
from repro.core.termination import TerminationCriterion
from repro.core import run_experiment
from repro.data.tasks import build_task

task = build_task("genomic", n_clients=5, train_size=200, test_size=60,
                  val_size=40, seed=1)
res = run_experiment(task, method="llm-qfl", n_rounds=6, maxiter0=10,
                     llm_steps=20, select_frac=0.4, epsilon=2e-2, seed=1)

print(f"LLM reference losses: {[round(l, 3) for l in res.llm_losses]}\n")
term = TerminationCriterion(epsilon=2e-2, t_max=99)
for r in res.rounds:
    print(f"--- round {r.t} ---")
    l0, llm0 = r.client_losses[0], res.llm_losses[0]
    print(f"device0: qnn_loss={l0:.3f} llm_loss={llm0:.3f} "
          f"ratio={l0/llm0:.2f}")
    for v in regulation.VARIANTS:
        print(f"  regulate[{v:11s}]: 10 -> "
              f"{regulation.regulate(10, l0, llm0, variant=v)}")
    d = selection.distances(r.client_losses, r.server_loss)
    print(f"  distances d_i = {np.round(d, 3)} -> selected {r.selected}")
    stop = term.update(r.server_loss, r.t)
    print(f"  server_loss={r.server_loss:.4f}  terminate={stop}")
print(f"\nrun stopped early: {res.terminated_early} "
      f"({len(res.rounds)} rounds)")
