"""LM LoRA fine-tuning driver on the model substrate (any --arch).

Runs the exact ``train_step`` the production dry-run lowers — LoRA
adapters + AdamW, frozen base, microbatch accumulation — at smoke scale by
default (CPU) or full scale with --full (TPU pods; pair with
repro.launch.dryrun for the mesh).

  PYTHONPATH=src python examples/train_lm.py --arch xlstm-125m --steps 20
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get
from repro.models import model as M
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--full", action="store_true",
                    help="full config (TPU scale) instead of -smoke")
    args = ap.parse_args()

    cfg = get(args.arch if args.full else args.arch + "-smoke")
    print(f"fine-tuning {cfg.name} ({cfg.param_count()/1e6:.1f}M params, "
          f"LoRA r={cfg.lora.rank})")
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    adapters = M.init_adapters(cfg, key, params)
    opt = adamw.init(adapters)
    step = jax.jit(M.make_train_step(cfg, n_microbatches=args.microbatches,
                                     lr=args.lr))

    # synthetic LM data: fixed random document the adapters memorize
    doc = jax.random.randint(key, (args.batch, args.seq + 1), 4,
                             cfg.vocab_size - 4)
    batch = {"tokens": doc[:, :-1], "labels": doc[:, 1:]}

    t0 = time.time()
    for s in range(args.steps):
        adapters, opt, m = step(params, adapters, opt, batch)
        if s % 5 == 0 or s == args.steps - 1:
            print(f"step {s:4d}  loss={float(m['loss']):.4f}  "
                  f"gnorm={float(m['grad_norm']):.2f}")
    dt = time.time() - t0
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"{args.steps} steps in {dt:.1f}s ({tok_s:.0f} tok/s CPU)")


if __name__ == "__main__":
    main()
