"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.peft.lora import quantize

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
           dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("M,K,N,r", [
    (128, 256, 128, 8), (256, 512, 384, 16), (64, 128, 512, 4),
    (32, 64, 64, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lora_matmul(M, K, N, r, dtype):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (M, K), dtype)
    w = (jax.random.normal(ks[1], (K, N), jnp.float32) * 0.05).astype(dtype)
    a = (jax.random.normal(ks[2], (K, r), jnp.float32) * 0.05).astype(dtype)
    b = (jax.random.normal(ks[3], (r, N), jnp.float32) * 0.05).astype(dtype)
    got = ops.lora_matmul(x, w, a, b, scale=2.0)
    want = ref.lora_matmul(x, w, a, b, 2.0)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


@pytest.mark.parametrize("M,K,N", [(128, 256, 256), (64, 512, 384),
                                   (256, 128, 512)])
@pytest.mark.parametrize("qblock", [32, 64])
def test_int4_matmul(M, K, N, qblock):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (M, K), jnp.float32)
    w = jax.random.normal(ks[1], (K, N), jnp.float32) * 0.05
    packed, scales = quantize(w, qblock)
    got = ops.int4_matmul(x, packed, scales, qblock=qblock)
    want = ref.int4_matmul(x, packed, scales, qblock)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,C", [(64, 2), (256, 3), (512, 7), (100, 10)])
def test_distill_kl(B, C):
    ks = jax.random.split(KEY, 2)
    t = jax.nn.softmax(jax.random.normal(ks[0], (B, C)), -1)
    z = jax.random.normal(ks[1], (B, C)) * 3.0
    got = ops.distill_kl(t, z)
    want = ref.distill_kl(t, z)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    assert (np.asarray(got) >= -1e-6).all()   # KL non-negativity


@pytest.mark.parametrize("B,H,S,D", [(1, 2, 128, 64), (2, 4, 256, 64),
                                     (1, 1, 512, 128)])
@pytest.mark.parametrize("window", [0, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, S, D, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, H, S, D), dtype)
    v = jax.random.normal(ks[2], (B, H, S, D), dtype)
    got = ops.flash_attention(q, k, v, causal=True, window=window)
    want = ref.flash_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


def test_flash_non_causal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 32))
    k = jax.random.normal(ks[1], (1, 2, 128, 32))
    v = jax.random.normal(ks[2], (1, 2, 128, 32))
    got = ops.flash_attention(q, k, v, causal=False)
    want = ref.flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_jnp_chunked_flash_matches_kernel_ref():
    """The model-internal chunked jnp flash (attention.py) must agree with
    the kernel oracle too — same math, different tiling."""
    from repro.models.attention import flash_attention as jnp_flash
    ks = jax.random.split(KEY, 3)
    B, S, H, D = 2, 256, 4, 32
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    got = jnp_flash(q, k, v, causal=True, q_chunk=64, k_chunk=64)
    want = ref.flash_attention(q.transpose(0, 2, 1, 3),
                               k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3), causal=True)
    np.testing.assert_allclose(got.transpose(0, 2, 1, 3), want,
                               rtol=2e-5, atol=2e-5)
