"""PEFT: LoRA merge equivalence, QLoRA quantization error bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get
from repro.models import model as M
from repro.models.common import dense, lora_pair
from repro.peft import lora

KEY = jax.random.PRNGKey(11)


def test_lora_merge_equivalence():
    """merged-weights forward ≡ adapter-path forward (property from
    DESIGN.md §8)."""
    cfg = get("stablelm-3b-smoke")
    p = M.init_params(cfg, KEY)
    a = M.init_adapters(cfg, KEY, p)
    # give the b-matrices real values (init is zeros)
    a = jax.tree.map(lambda x: x + 0.01, a)

    layer0 = jax.tree.map(lambda x: x[0], p["groups"][0])
    adp0 = jax.tree.map(lambda x: x[0], a["groups"][0])
    x = jax.random.normal(KEY, (2, 8, cfg.d_model), jnp.float32)

    combined = {**layer0, **adp0}
    y_adapter = dense(x, layer0["wq"], lora_pair(combined, "wq", cfg.lora))
    merged = lora.merge_layer(cfg, layer0, adp0)
    y_merged = dense(x, merged["wq"].astype(jnp.float32))
    # merged path re-quantizes to the base dtype (bf16): one half-ulp of
    # bf16 at activation scale ~2 is ~8e-3
    np.testing.assert_allclose(np.asarray(y_adapter), np.asarray(y_merged),
                               rtol=2e-2, atol=1e-2)


def test_adapter_count_is_small():
    cfg = get("llama3-405b-smoke")
    p = M.init_params(cfg, KEY)
    a = M.init_adapters(cfg, KEY, p)
    n_base = sum(int(jnp.size(x)) for x in jax.tree.leaves(p))
    n_adp = lora.adapter_param_count(a)
    assert n_adp < 0.2 * n_base


@given(st.integers(1, 4), st.floats(0.01, 2.0))
@settings(max_examples=15, deadline=None)
def test_quantize_dequantize_error_bound(seed, scale):
    """Blockwise int4 absmax: |w − deq(q(w))| ≤ absmax/7/2 per block."""
    k = jax.random.PRNGKey(seed)
    w = jax.random.normal(k, (32, 128), jnp.float32) * scale
    packed, scales = lora.quantize(w, 64)
    deq = lora.dequantize(packed, scales, 64, dtype=jnp.float32)
    wb = np.asarray(w).reshape(32, 2, 64)
    bound = np.abs(wb).max(-1) / 7.0 / 2.0 + 1e-6
    err = np.abs(np.asarray(deq).reshape(32, 2, 64) - wb).max(-1)
    assert (err <= bound + 1e-5).all()


def test_quantize_pack_shapes():
    w = jax.random.normal(KEY, (16, 256), jnp.float32)
    packed, scales = lora.quantize(w, 64)
    assert packed.shape == (16, 128) and packed.dtype == jnp.uint8
    assert scales.shape == (16, 4)


def test_quantize_tree_targets_only():
    tree = {"wq": jnp.ones((8, 64)), "ln": jnp.ones((8,)),
            "nested": {"w_in": jnp.ones((8, 64)), "bias": jnp.ones((64,))}}
    qt = lora.quantize_tree(tree, targets=("wq", "w_in"))
    assert set(qt["wq"].keys()) == {"q", "s"}
    assert set(qt["nested"]["w_in"].keys()) == {"q", "s"}
    assert qt["ln"].shape == (8,)
    assert qt["nested"]["bias"].shape == (64,)
