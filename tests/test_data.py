"""Data pipelines: generators, tokenizers, PCA, federated splits."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import federated, genomic, pca, tokenizer, tweets
from repro.data.tasks import build_task


def test_genomic_shapes_and_learnability():
    seqs, labels = genomic.generate(400, seed=1)
    assert seqs.shape == (400, 200) and set(np.unique(labels)) <= {0, 1}
    assert seqs.min() >= 0 and seqs.max() <= 3
    # GC content separates classes (planted signal)
    gc = ((seqs == 1) | (seqs == 2)).mean(axis=1)
    assert gc[labels == 0].mean() > gc[labels == 1].mean()


def test_genomic_onehot_roundtrip():
    seqs, _ = genomic.generate(10, seed=2)
    oh = genomic.one_hot(seqs)
    assert oh.shape == (10, 800)
    np.testing.assert_allclose(oh.reshape(10, 200, 4).sum(-1), 1.0)
    assert np.argmax(oh.reshape(10, 200, 4), -1).astype(np.int8).tolist() \
        == seqs.tolist()


def test_tweets_generator():
    texts, labels = tweets.generate(300, seed=3)
    assert len(texts) == 300 and set(np.unique(labels)) <= {0, 1, 2}
    f = tweets.bag_features(texts)
    # positive tweets carry more positive words
    assert f[labels == 2, 0].mean() > f[labels == 0, 0].mean()
    assert f[labels == 0, 1].mean() > f[labels == 2, 1].mean()


def test_kmer_tokenizer():
    tok = tokenizer.KmerTokenizer(k=6, n_labels=2)
    assert tok.vocab_size == 4 + 4096 + 2
    ids = tok.encode("ACGTAC" * 5)
    assert ids[0] == tokenizer.BOS and len(ids) == 1 + 5
    assert tok.label_token(0) == tok.vocab_size - 2
    assert tok.label_token(1) == tok.vocab_size - 1


def test_pack_classification_masks():
    tok = tokenizer.KmerTokenizer(k=6, n_labels=2)
    lists = [tok.encode("ACGTAC" * 4), tok.encode("ACGTAC" * 2)]
    batch = tokenizer.pack_classification(lists, np.array([1, 0]), tok, 16)
    ys = batch["labels"]
    assert (ys >= 0).sum(axis=1).tolist() == [1, 1]     # one label pos each
    pos = np.argmax(ys >= 0, axis=1)
    assert ys[0, pos[0]] == tok.label_token(1)
    # teacher-forced label token present in the input stream
    assert batch["tokens"][0, pos[0] + 1] == tok.label_token(1)


def test_pca_projects_to_pi_box():
    X = np.random.default_rng(0).normal(size=(300, 50)).astype(np.float32)
    p = pca.fit(X, 4)
    Z = p.transform(X)
    assert Z.shape == (300, 4)
    assert Z.min() >= 0.0 and Z.max() <= np.pi + 1e-6


def test_pca_orthonormal_components():
    X = np.random.default_rng(1).normal(size=(200, 30))
    p = pca.fit(X, 4, scale_to_pi=False)
    gram = p.components.T @ p.components
    np.testing.assert_allclose(gram, np.eye(4), atol=1e-8)


@given(st.integers(2, 12), st.floats(0.1, 5.0))
@settings(max_examples=20, deadline=None)
def test_dirichlet_split_partitions(n_clients, alpha):
    labels = np.random.default_rng(0).integers(0, 3, 400)
    shards = federated.split_dirichlet(labels, n_clients, alpha=alpha,
                                       seed=1)
    allidx = np.concatenate(shards)
    assert len(allidx) == 400 and len(np.unique(allidx)) == 400
    assert min(len(s) for s in shards) >= 8


def test_client_weights_sum_to_one():
    shards = [np.arange(10), np.arange(30), np.arange(60)]
    w = federated.client_weights(shards)
    assert w.sum() == pytest.approx(1.0)
    np.testing.assert_allclose(w, [0.1, 0.3, 0.6])


def test_build_task_end_to_end():
    t = build_task("genomic", n_clients=4, train_size=200, test_size=50,
                   val_size=25, non_iid_alpha=0.5, seed=9)
    assert t.n_clients == 4 and sum(c.n for c in t.clients) == 200
    assert t.test_qX.shape == (50, 4) and t.val_qX.shape == (25, 4)
    assert t.weights.sum() == pytest.approx(1.0)
    for c in t.clients:
        assert c.llm_batch["tokens"].shape[1] == t.llm_seq_len
