"""Engine parity: batched (fused device program) vs sequential reference.

The batched engine must reproduce the sequential trajectories — same
perturbation draws (SPSA) or same branch decisions (Nelder–Mead), same
update law, same regulation, same eval accounting — up to f32/f64
arithmetic-order noise, for both native optimizers.
"""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.orchestrator import run_experiment
from repro.data.tasks import build_task
from repro.optim import gradfree
from repro.optim.batched_spsa import batched_spsa, make_deltas


@pytest.fixture(scope="module")
def small_task():
    return build_task("genomic", n_clients=3, train_size=90, test_size=45,
                      val_size=30, seed=5)


def _pair(task, **kw):
    seq = run_experiment(task, engine="sequential", **kw)
    bat = run_experiment(task, engine="batched", **kw)
    return seq, bat


# --- unit: masked batched SPSA vs the sequential scalar SPSA -----------------
def test_batched_spsa_matches_sequential_per_client():
    dim, iters = 6, np.array([7, 3, 0])
    seeds = [101, 202, 303]
    deltas = make_deltas(seeds, 8, dim)

    def quad(c):
        center = np.linspace(-1, 1, dim) * (c + 1)
        return lambda x: float(np.sum((np.asarray(x) - center) ** 2))

    x0 = np.full((3, dim), 0.5)
    f = lambda xs: jnp.sum(
        (xs - jnp.linspace(-1, 1, dim)[None, :]
         * (jnp.arange(3, dtype=jnp.float32) + 1)[:, None]) ** 2, axis=-1)
    x, f_final, n_evals = batched_spsa(f, x0, iters, deltas)

    for c in range(3):
        st = gradfree.spsa_init(quad(c), x0[c], seed=seeds[c])
        st = gradfree.spsa_run(quad(c), st, int(iters[c]))
        np.testing.assert_allclose(np.asarray(x[c]), st.x, atol=2e-5)
        assert int(n_evals[c]) == st.n_evals

    # zero-budget client never moves
    np.testing.assert_allclose(np.asarray(x[2]), x0[2], atol=0)


def test_make_deltas_matches_gradfree_draw_order():
    """Same rng construction + per-iteration draw as gradfree.spsa_run."""
    seed, m, dim = 42, 5, 4
    want = []
    rng = gradfree.spsa_rng(seed, 0)    # fresh run: k = 0
    for _ in range(m):
        want.append(rng.choice([-1.0, 1.0], size=dim))
    got = make_deltas([seed], m, dim)[0]
    np.testing.assert_array_equal(got, np.stack(want))


# --- integration: run_experiment trajectories --------------------------------
def test_qfl_spsa_engine_parity(small_task):
    kw = dict(method="qfl", optimizer="spsa", n_rounds=3, maxiter0=5,
              early_stop=False)
    seq, bat = _pair(small_task, **kw)
    np.testing.assert_allclose(bat.series("server_loss"),
                               seq.series("server_loss"), atol=1e-4)
    np.testing.assert_allclose(bat.theta_g, seq.theta_g, atol=1e-4)
    assert bat.series("maxiters") == seq.series("maxiters")
    assert bat.series("cum_evals") == seq.series("cum_evals")
    assert bat.series("selected") == seq.series("selected")


def test_llm_qfl_spsa_engine_parity(small_task):
    """Full Alg. 1: distillation objective + regulated budgets, batched."""
    kw = dict(method="llm-qfl", optimizer="spsa", n_rounds=3, maxiter0=5,
              llm_steps=8, early_stop=False, seed=2)
    seq, bat = _pair(small_task, **kw)
    # regulation consumed identical losses → identical integer budgets
    assert bat.series("maxiters") == seq.series("maxiters")
    assert bat.series("cum_evals") == seq.series("cum_evals")
    np.testing.assert_allclose(bat.series("server_loss"),
                               seq.series("server_loss"), atol=1e-4)
    np.testing.assert_allclose(bat.theta_g, seq.theta_g, atol=1e-3)


def test_qcnn_tweets_engine_parity():
    """3-class tweets task exercises the QCNN tape + parity interpret."""
    task = build_task("tweets", n_clients=3, train_size=60, test_size=24,
                      val_size=24, seed=7)
    seq, bat = _pair(task, method="qfl", optimizer="spsa", n_rounds=2,
                     maxiter0=4, early_stop=False)
    np.testing.assert_allclose(bat.series("server_loss"),
                               seq.series("server_loss"), atol=1e-4)
    np.testing.assert_allclose(bat.theta_g, seq.theta_g, atol=1e-4)
    assert bat.series("cum_evals") == seq.series("cum_evals")


def test_qfl_nelder_mead_engine_parity(small_task):
    """The paper's default optimizer runs natively on the batched engine:
    same trajectories, same branch-dependent eval counts — no warning."""
    kw = dict(method="qfl", optimizer="nelder-mead", n_rounds=3,
              maxiter0=5, early_stop=False)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        seq, bat = _pair(small_task, **kw)
    # the old NM→SPSA-mask fallback warned; native NM must not
    assert not [w for w in caught if "SPSA" in str(w.message)]
    np.testing.assert_allclose(bat.series("server_loss"),
                               seq.series("server_loss"), atol=1e-5)
    assert abs(bat.rounds[-1].server_loss
               - seq.rounds[-1].server_loss) <= 1e-5
    np.testing.assert_allclose(bat.theta_g, seq.theta_g, atol=1e-4)
    assert bat.series("maxiters") == seq.series("maxiters")
    assert bat.series("cum_evals") == seq.series("cum_evals")
    assert bat.series("selected") == seq.series("selected")


def test_llm_qfl_nelder_mead_engine_parity(small_task):
    """Full Alg. 1 with the default optimizer: regulation consumes
    identical losses → identical budgets → identical simplex branches."""
    kw = dict(method="llm-qfl", optimizer="nelder-mead", n_rounds=3,
              maxiter0=5, llm_steps=8, early_stop=False, seed=2)
    seq, bat = _pair(small_task, **kw)
    assert bat.series("maxiters") == seq.series("maxiters")
    assert bat.series("cum_evals") == seq.series("cum_evals")
    np.testing.assert_allclose(bat.series("server_loss"),
                               seq.series("server_loss"), atol=1e-4)
    assert abs(bat.rounds[-1].server_loss
               - seq.rounds[-1].server_loss) <= 1e-5
    assert any(m != 5 for r in bat.rounds[1:] for m in r.maxiters)


def test_noisy_engine_parity_spsa(small_task):
    """Finite-shot fake backend: the keyed slot schedule gives both
    engines the same key per evaluation, so shot-count draws coincide
    and trajectories agree to arithmetic-order noise with exact
    budget/eval accounting.  (Seeds are pinned: the tape and eager
    forwards differ by ~2e-7, so an unlucky draw inside that sliver of
    a class boundary could flip one shot — these seeds have none.)"""
    kw = dict(method="qfl", optimizer="spsa", n_rounds=2, maxiter0=4,
              early_stop=False, backend="fake", seed=4)
    seq, bat = _pair(small_task, **kw)
    gap = max(abs(a - b) for a, b in zip(seq.series("server_loss"),
                                         bat.series("server_loss")))
    assert gap <= 3e-7
    assert bat.series("maxiters") == seq.series("maxiters")
    assert bat.series("cum_evals") == seq.series("cum_evals")
    assert bat.series("selected") == seq.series("selected")
    np.testing.assert_allclose(bat.theta_g, seq.theta_g, atol=1e-4)


def test_noisy_engine_parity_nelder_mead(small_task):
    """Shot sampling through the speculative NM candidate batch: branch
    decisions (hence branch-dependent eval counts) match the lazy
    sequential evaluation because every candidate owns its slot."""
    for backend in ("fake", "aersim"):
        kw = dict(method="qfl", optimizer="nelder-mead", n_rounds=3,
                  maxiter0=5, early_stop=False, backend=backend)
        seq, bat = _pair(small_task, **kw)
        gap = max(abs(a - b) for a, b in zip(seq.series("server_loss"),
                                             bat.series("server_loss")))
        assert gap <= 3e-7
        assert bat.series("cum_evals") == seq.series("cum_evals")
        assert bat.series("selected") == seq.series("selected")


def test_noisy_llm_qfl_regulated_parity(small_task):
    """Full Alg. 1 on a finite-shot backend: regulation consumes
    identical (sampled) losses → identical integer budgets, and the
    distillation objective samples only its F_i term in both engines."""
    kw = dict(method="llm-qfl", optimizer="nelder-mead", n_rounds=3,
              maxiter0=5, llm_steps=8, early_stop=False, seed=2,
              backend="fake")
    seq, bat = _pair(small_task, **kw)
    assert bat.series("maxiters") == seq.series("maxiters")
    assert bat.series("cum_evals") == seq.series("cum_evals")
    gap = max(abs(a - b) for a, b in zip(seq.series("server_loss"),
                                         bat.series("server_loss")))
    assert gap <= 3e-7


def test_batched_engine_comm_accounting(small_task):
    """Latency model sees exactly the sequential path's metered-run evals
    (init is not comm-billed) for both optimizers."""
    for optimizer in ("spsa", "nelder-mead"):
        seq, bat = _pair(small_task, method="qfl", optimizer=optimizer,
                         n_rounds=2, maxiter0=4, early_stop=False,
                         backend="fake")
        for rs, rb in zip(seq.rounds, bat.rounds):
            assert rb.comm_time_s == pytest.approx(rs.comm_time_s,
                                                   rel=1e-9)


def test_batched_engine_six_qubits_smoke():
    """ROADMAP scale knob: n_qubits is config, the tape compiler is
    n-generic, and the batched engine runs a 6-qubit VQC end to end."""
    task = build_task("genomic", n_clients=3, train_size=45, test_size=15,
                      val_size=15, seed=3, n_features=6)
    res = run_experiment(task, method="qfl", optimizer="nelder-mead",
                         engine="batched", n_qubits=6, n_rounds=2,
                         maxiter0=3, early_stop=False)
    assert len(res.rounds) == 2
    assert all(np.isfinite(r.server_loss) for r in res.rounds)
    from repro.quantum import qnn
    assert res.theta_g.shape == (
        qnn.QNNSpec("vqc", n_qubits=6).n_params,)


def test_n_qubits_must_match_task_features(small_task):
    with pytest.raises(ValueError):
        run_experiment(small_task, n_qubits=6, n_rounds=1)


def test_unknown_engine_rejected(small_task):
    with pytest.raises(ValueError):
        run_experiment(small_task, engine="warp-drive", n_rounds=1)
