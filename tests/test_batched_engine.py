"""Engine parity: batched (fused device program) vs sequential reference.

The batched engine must reproduce the sequential trajectories — same
perturbation draws, same update law, same regulation — up to f32/f64
arithmetic-order noise, for native SPSA; the Nelder–Mead config maps its
regulated budgets onto SPSA iteration masks and must stay well-behaved.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.orchestrator import run_experiment
from repro.data.tasks import build_task
from repro.optim import gradfree
from repro.optim.batched_spsa import batched_spsa, make_deltas


@pytest.fixture(scope="module")
def small_task():
    return build_task("genomic", n_clients=3, train_size=90, test_size=45,
                      val_size=30, seed=5)


def _pair(task, **kw):
    seq = run_experiment(task, engine="sequential", **kw)
    bat = run_experiment(task, engine="batched", **kw)
    return seq, bat


# --- unit: masked batched SPSA vs the sequential scalar SPSA -----------------
def test_batched_spsa_matches_sequential_per_client():
    dim, iters = 6, np.array([7, 3, 0])
    seeds = [101, 202, 303]
    deltas = make_deltas(seeds, 8, dim)

    def quad(c):
        center = np.linspace(-1, 1, dim) * (c + 1)
        return lambda x: float(np.sum((np.asarray(x) - center) ** 2))

    x0 = np.full((3, dim), 0.5)
    f = lambda xs: jnp.sum(
        (xs - jnp.linspace(-1, 1, dim)[None, :]
         * (jnp.arange(3, dtype=jnp.float32) + 1)[:, None]) ** 2, axis=-1)
    x, f_final, n_evals = batched_spsa(f, x0, iters, deltas)

    for c in range(3):
        st = gradfree.spsa_init(quad(c), x0[c], seed=seeds[c])
        st = gradfree.spsa_run(quad(c), st, int(iters[c]))
        np.testing.assert_allclose(np.asarray(x[c]), st.x, atol=2e-5)
        assert int(n_evals[c]) == st.n_evals

    # zero-budget client never moves
    np.testing.assert_allclose(np.asarray(x[2]), x0[2], atol=0)


def test_make_deltas_matches_gradfree_draw_order():
    """Same rng construction + per-iteration draw as gradfree.spsa_run."""
    seed, m, dim = 42, 5, 4
    want = []
    rng = np.random.default_rng(seed)
    for _ in range(m):
        want.append(rng.choice([-1.0, 1.0], size=dim))
    got = make_deltas([seed], m, dim)[0]
    np.testing.assert_array_equal(got, np.stack(want))


# --- integration: run_experiment trajectories --------------------------------
def test_qfl_spsa_engine_parity(small_task):
    kw = dict(method="qfl", optimizer="spsa", n_rounds=3, maxiter0=5,
              early_stop=False)
    seq, bat = _pair(small_task, **kw)
    np.testing.assert_allclose(bat.series("server_loss"),
                               seq.series("server_loss"), atol=1e-4)
    np.testing.assert_allclose(bat.theta_g, seq.theta_g, atol=1e-4)
    assert bat.series("maxiters") == seq.series("maxiters")
    assert bat.series("cum_evals") == seq.series("cum_evals")
    assert bat.series("selected") == seq.series("selected")


def test_llm_qfl_spsa_engine_parity(small_task):
    """Full Alg. 1: distillation objective + regulated budgets, batched."""
    kw = dict(method="llm-qfl", optimizer="spsa", n_rounds=3, maxiter0=5,
              llm_steps=8, early_stop=False, seed=2)
    seq, bat = _pair(small_task, **kw)
    # regulation consumed identical losses → identical integer budgets
    assert bat.series("maxiters") == seq.series("maxiters")
    assert bat.series("cum_evals") == seq.series("cum_evals")
    np.testing.assert_allclose(bat.series("server_loss"),
                               seq.series("server_loss"), atol=1e-4)
    np.testing.assert_allclose(bat.theta_g, seq.theta_g, atol=1e-3)


def test_qcnn_tweets_engine_parity():
    """3-class tweets task exercises the QCNN tape + parity interpret."""
    task = build_task("tweets", n_clients=3, train_size=60, test_size=24,
                      val_size=24, seed=7)
    seq, bat = _pair(task, method="qfl", optimizer="spsa", n_rounds=2,
                     maxiter0=4, early_stop=False)
    np.testing.assert_allclose(bat.series("server_loss"),
                               seq.series("server_loss"), atol=1e-4)
    np.testing.assert_allclose(bat.theta_g, seq.theta_g, atol=1e-4)
    assert bat.series("cum_evals") == seq.series("cum_evals")


def test_nelder_mead_budgets_map_onto_spsa_masks(small_task):
    """optimizer="nelder-mead" + engine="batched": regulated budgets drive
    SPSA iteration masks; run must regulate, converge, and account evals
    as 3·maxiter + 2 per client per round."""
    res = run_experiment(small_task, method="llm-qfl",
                         optimizer="nelder-mead", engine="batched",
                         n_rounds=3, maxiter0=5, llm_steps=8,
                         early_stop=False, seed=2)
    assert len(res.rounds) == 3
    assert all(np.isfinite(r.server_loss) for r in res.rounds)
    assert res.rounds[-1].server_loss <= res.rounds[0].server_loss * 1.5
    assert any(m != 5 for r in res.rounds[1:] for m in r.maxiters)
    expect = [3 * m + 2 for m in res.rounds[0].maxiters]
    assert res.rounds[0].cum_evals == expect


def test_batched_engine_comm_accounting(small_task):
    """Latency model sees 3·maxiter+1 post-init evals, like sequential."""
    seq, bat = _pair(small_task, method="qfl", optimizer="spsa",
                     n_rounds=2, maxiter0=4, early_stop=False,
                     backend="fake")
    for rs, rb in zip(seq.rounds, bat.rounds):
        assert rb.comm_time_s == pytest.approx(rs.comm_time_s, rel=1e-9)


def test_unknown_engine_rejected(small_task):
    with pytest.raises(ValueError):
        run_experiment(small_task, engine="warp-drive", n_rounds=1)
