"""Quantum substrate: statevector invariants, circuits, QNN, backends."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.quantum import backends, circuits as C, qnn, statevector as sv

KEY = jax.random.PRNGKey(3)


# --- statevector engine -----------------------------------------------------
def test_zero_state():
    psi = sv.zero_state(3)
    p = sv.probabilities(psi)
    assert p[0] == pytest.approx(1.0)
    assert float(sv.norm(psi)) == pytest.approx(1.0)


@given(st.integers(2, 6), st.integers(0, 5),
       st.floats(-3.0, 3.0, allow_nan=False))
@settings(max_examples=25, deadline=None)
def test_gates_preserve_norm(n, qi, theta):
    q = qi % n
    psi = sv.zero_state(n)
    psi = sv.h(psi, q)
    psi = sv.rx(psi, theta, q)
    psi = sv.ry(psi, theta, (q + 1) % n)
    psi = sv.rz(psi, theta, q)
    psi = sv.cx(psi, q, (q + 1) % n)
    psi = sv.cz(psi, q, (q + 1) % n)
    assert float(sv.norm(psi)) == pytest.approx(1.0, abs=1e-5)


def test_x_flips():
    psi = sv.x(sv.zero_state(2), 0)
    p = sv.probabilities(psi)           # big-endian: |10> = index 2
    assert p[2] == pytest.approx(1.0)


def test_cx_entangles():
    psi = sv.h(sv.zero_state(2), 0)
    psi = sv.cx(psi, 0, 1)              # Bell state
    p = sv.probabilities(psi)
    np.testing.assert_allclose(p, [0.5, 0, 0, 0.5], atol=1e-6)


def test_expect_z():
    psi = sv.zero_state(1)
    assert float(sv.expect_z(psi, 0)) == pytest.approx(1.0)
    psi = sv.x(psi, 0)
    assert float(sv.expect_z(psi, 0)) == pytest.approx(-1.0)


# --- circuits ----------------------------------------------------------------
def test_feature_map_norm_and_sensitivity():
    x1 = jnp.array([0.3, 1.2, 2.0, 0.7])
    x2 = x1.at[0].add(0.5)
    p1, p2 = C.zz_feature_map(x1), C.zz_feature_map(x2)
    assert float(sv.norm(p1)) == pytest.approx(1.0, abs=1e-5)
    assert float(jnp.abs(p1 - p2).max()) > 1e-3   # encodes the feature


def test_real_amplitudes_param_count():
    psi = sv.zero_state(4)
    n = C.real_amplitudes_n_params(4, reps=3)
    assert n == 16
    theta = jnp.linspace(-1, 1, n)
    out = C.real_amplitudes(psi, theta, reps=3)
    assert float(sv.norm(out)) == pytest.approx(1.0, abs=1e-5)


def test_qcnn_reduces_to_one_qubit():
    psi = sv.zero_state(4)
    n = C.qcnn_n_params(4)
    psi, q = C.qcnn(psi, jnp.linspace(-2, 2, n))
    assert 0 <= q < 4
    assert float(sv.norm(psi)) == pytest.approx(1.0, abs=1e-5)


def test_qcnn_param_count_formula():
    assert C.qcnn_n_params(4) == 18    # stage1: 2 pairs ×6, stage2: 1 pair ×6
    assert C.qcnn_n_params(8) == 42


# --- QNN ----------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["vqc", "qcnn"])
def test_qnn_probs_simplex(kind):
    spec = qnn.QNNSpec(kind, n_qubits=4)
    th = spec.init_params(KEY)
    X = jax.random.uniform(KEY, (16, 4), jnp.float32, 0, np.pi)
    p = qnn.make_forward(spec)(th, X)
    assert p.shape == (16, 2)
    np.testing.assert_allclose(np.asarray(p.sum(1)), 1.0, atol=1e-5)
    assert (np.asarray(p) >= -1e-6).all()


def test_parity_interpret():
    # 2 qubits: parity of |00>=0, |01>=1, |10>=1, |11>=0
    probs = jnp.array([[0.1, 0.2, 0.3, 0.4]])
    out = qnn.parity_interpret(probs, 2, 2)
    np.testing.assert_allclose(out[0], [0.5, 0.5], atol=1e-6)


def test_qnn_trains():
    spec = qnn.QNNSpec("vqc", n_qubits=4)
    X = jax.random.uniform(KEY, (32, 4), jnp.float32, 0, np.pi)
    y = (X[:, 0] > np.pi / 2).astype(jnp.int32)
    loss = qnn.make_loss_fn(spec, X, y)
    from repro.optim.gradfree import GradFreeOptimizer
    th0 = np.asarray(spec.init_params(KEY))
    f0 = float(loss(jnp.asarray(th0, jnp.float32)))
    opt = GradFreeOptimizer(
        lambda t: float(loss(jnp.asarray(t, jnp.float32))), th0)
    _, f1 = opt.run(40)
    assert f1 < f0


# --- backends -------------------------------------------------------------------
def test_backend_noise_keeps_simplex():
    p = jnp.array([[0.9, 0.1], [0.2, 0.8]])
    for b in backends.BACKENDS.values():
        out = b.transform_probs(p, key=KEY)
        np.testing.assert_allclose(np.asarray(out.sum(1)), 1.0, atol=1e-5)


def test_shot_sampling_concentrates():
    p = jnp.array([[0.75, 0.25]])
    counts = backends.sample_counts(KEY, p, 1000)
    assert abs(float(counts[0, 0]) / 1000 - 0.75) < 0.05


def test_shot_sampling_distribution_and_shape():
    """sample_counts draws per-row multinomials without materializing a
    (B, shots, C) tensor: counts sum to shots and the empirical
    frequencies converge to the row distributions."""
    p = jnp.array([[0.6, 0.3, 0.1],
                   [0.05, 0.05, 0.9],
                   [1 / 3, 1 / 3, 1 / 3]])
    shots = 20000
    counts = backends.sample_counts(KEY, p, shots)
    assert counts.shape == p.shape
    np.testing.assert_allclose(np.asarray(counts.sum(axis=1)), shots)
    np.testing.assert_allclose(np.asarray(counts) / shots, np.asarray(p),
                               atol=0.02)


def test_latency_ordering_matches_table1():
    """Table I: Fake < AerSim < Real comm time."""
    n = 100
    t = [backends.get(k).eval_time(n) for k in ("fake", "aersim", "real")]
    assert t[0] < t[1] < t[2]


def test_depolarizing_pulls_to_uniform():
    b = backends.Backend("x", depolarizing=1.0)
    p = jnp.array([[1.0, 0.0]])
    np.testing.assert_allclose(b.transform_probs(p)[0], [0.5, 0.5],
                               atol=1e-6)
