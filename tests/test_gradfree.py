"""Gradient-free optimizers: maxiter semantics, resumability, convergence."""
import numpy as np
import pytest

from repro.optim.gradfree import (GradFreeOptimizer, nm_init, nm_run,
                                  spsa_init, spsa_rng, spsa_run)
from repro.quantum.backends import FINAL_EVAL_SLOT


def quad(x):
    return float(np.sum((x - 1.0) ** 2))


def _recording_stream(slots):
    """key_stream stub: records the contract slot of every evaluation."""
    return lambda slot: slots.append(slot)


def test_nm_key_stream_slot_schedule():
    """Keyed NM evaluates on the contract slots: init rows 0..n, then
    per (global) iteration i with base=(n+1)+i·(n+3): reflect→base,
    expand→base+1, contract→base+2, shrink row j→base+2+j — the same
    schedule batched_nm drives, so draws match engine-for-engine."""
    n = 3
    slots, trace = [], []
    fn = lambda x, key=None: quad(x)
    st = nm_init(fn, np.zeros(n), key_stream=_recording_stream(slots))
    assert slots == list(range(n + 1))

    iters = 8
    slots.clear()
    st = nm_run(fn, st, iters, trace=trace,
                key_stream=_recording_stream(slots))
    want = []
    for i, branch in enumerate(trace):
        base = (n + 1) + i * (n + 3)
        want.append(base)                        # reflect, always
        if branch in (0, 1):
            want.append(base + 1)                # expand
        elif branch in (3, 4):
            want.append(base + 2)                # contract
            if branch == 4:
                want.extend(base + 2 + j for j in range(1, n + 1))
    assert slots == want

    # resume: global n_iters keeps advancing the slot bases
    slots.clear()
    nm_run(fn, st, 1, key_stream=_recording_stream(slots))
    assert slots[0] == (n + 1) + iters * (n + 3)


def test_spsa_key_stream_slot_schedule():
    """Keyed SPSA slots: init→0, iteration k→{1,2,3}+3k, final polish→
    FINAL_EVAL_SLOT; resumes continue from the global counter."""
    slots = []
    fn = lambda x, key=None: quad(x)
    st = spsa_init(fn, np.zeros(4), seed=0,
                   key_stream=_recording_stream(slots))
    assert slots == [0]
    slots.clear()
    st = spsa_run(fn, st, 2, key_stream=_recording_stream(slots))
    assert slots == [1, 2, 3, 4, 5, 6, FINAL_EVAL_SLOT]
    slots.clear()
    spsa_run(fn, st, 1, key_stream=_recording_stream(slots))
    assert slots == [7, 8, 9, FINAL_EVAL_SLOT]


def test_keyed_and_unkeyed_trajectories_match_when_noise_free():
    """key_stream only changes the calling convention — with an
    objective that ignores the key, results are identical."""
    ks = lambda slot: None
    for method in ("nelder-mead", "spsa"):
        a = GradFreeOptimizer(quad, np.zeros(4), method=method, seed=3)
        b = GradFreeOptimizer(lambda x, key: quad(x), np.zeros(4),
                              method=method, seed=3, key_stream=ks)
        xa, fa = a.run(25)
        xb, fb = b.run(25)
        np.testing.assert_array_equal(xa, xb)
        assert fa == fb and a.n_evals == b.n_evals


def test_nm_converges_quadratic():
    opt = GradFreeOptimizer(quad, np.zeros(4))
    _, f = opt.run(150)
    assert f < 1e-6


def test_nm_maxiter_metering():
    st0 = nm_init(quad, np.zeros(3))
    st1 = nm_run(quad, st0, 10)
    assert st1.n_iters == 10
    st2 = nm_run(quad, st1, 7)
    assert st2.n_iters == 17
    assert st2.best_f <= st1.best_f            # monotone best


def test_nm_zero_iters_is_noop():
    st0 = nm_init(quad, np.zeros(3))
    st1 = nm_run(quad, st0, 0)
    assert st1.best_f == st0.best_f and st1.n_evals == st0.n_evals


def test_nm_resumable_equals_oneshot():
    one = nm_run(quad, nm_init(quad, np.zeros(3)), 30)
    two = nm_run(quad, nm_run(quad, nm_init(quad, np.zeros(3)), 15), 15)
    np.testing.assert_allclose(one.best_x, two.best_x, atol=1e-12)


def test_spsa_improves_and_resumes():
    opt = GradFreeOptimizer(quad, np.zeros(6), method="spsa", seed=1)
    f0 = opt.best[1]
    _, f1 = opt.run(150)
    assert f1 < f0
    _, f2 = opt.run(150)
    assert f2 <= f1 + 1e-9


def test_spsa_streams_decorrelated_across_clients():
    """Regression: federated client seeds are consecutive, so the old
    ``default_rng(seed + k)`` made client i resumed at iteration k replay
    client i+k's fresh Rademacher stream.  ``spsa_rng`` hashes the
    (seed, k) pair — every (client, resume-point) stream is distinct."""
    a = spsa_rng(5, 1).choice([-1.0, 1.0], size=64)
    b = spsa_rng(6, 0).choice([-1.0, 1.0], size=64)
    assert not np.array_equal(a, b)
    # the old scheme would have collided: default_rng(6) on both sides
    old_a = np.random.default_rng(5 + 1).choice([-1.0, 1.0], size=64)
    old_b = np.random.default_rng(6 + 0).choice([-1.0, 1.0], size=64)
    assert np.array_equal(old_a, old_b)
    # same pair → same stream (resumability stays deterministic)
    assert np.array_equal(spsa_rng(5, 1).choice([-1.0, 1.0], size=64),
                          spsa_rng(5, 1).choice([-1.0, 1.0], size=64))


def test_spsa_resume_uses_distinct_stream_from_fresh_run():
    """Resuming at k>0 must not replay the fresh-run draws: the (3, 5)
    stream is not the continuation of the (3, 0) stream, nor its start."""
    dim = 6
    fresh = spsa_rng(3, 0)
    fresh_prefix = fresh.choice([-1.0, 1.0], size=(5, dim))
    fresh_continuation = fresh.choice([-1.0, 1.0], size=(5, dim))
    resumed = spsa_rng(3, 5).choice([-1.0, 1.0], size=(5, dim))
    assert not np.array_equal(resumed, fresh_continuation)
    assert not np.array_equal(resumed, fresh_prefix)


def test_rosenbrock_both_methods_bounded():
    rosen = lambda x: float((1 - x[0]) ** 2 + 100 * (x[1] - x[0] ** 2) ** 2)
    for m in ("nelder-mead", "spsa"):
        opt = GradFreeOptimizer(rosen, np.array([-1.2, 1.0]), method=m)
        _, f = opt.run(250)
        assert np.isfinite(f) and f < rosen(np.array([-1.2, 1.0]))


def test_set_fn_keeps_geometry():
    opt = GradFreeOptimizer(quad, np.zeros(3))
    opt.run(20)
    shifted = lambda x: float(np.sum((x - 2.0) ** 2))
    opt.set_fn(shifted)
    x, f = opt.run(100)
    assert f < 1e-3 and np.allclose(x, 2.0, atol=0.05)
