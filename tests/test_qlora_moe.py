"""QLoRA-quantized model path + sort-based MoE dispatch invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get
from repro.models import model as M
from repro.peft import lora

KEY = jax.random.PRNGKey(0)


def _qcfg(name):
    cfg = get(name + "-smoke")
    return cfg, dataclasses.replace(
        cfg, lora=dataclasses.replace(cfg.lora, quantize_base=True))


def test_quantized_params_structure():
    cfg, qcfg = _qcfg("stablelm-3b")
    pq = M.init_params(qcfg, KEY)
    layer = pq["groups"][0]
    for t in ("wq", "wkv", "wo", "w_in", "w_out"):
        assert f"{t}__q" in layer and f"{t}__s" in layer
        assert t not in layer
        assert layer[f"{t}__q"].dtype == jnp.uint8


def test_quantized_forward_close_to_full():
    cfg, qcfg = _qcfg("stablelm-3b")
    p = M.init_params(cfg, KEY)
    pq = M.init_params(qcfg, KEY)
    a = M.init_adapters(cfg, KEY, p)
    aq = M.init_adapters(qcfg, KEY, pq)
    assert jax.tree.structure(a) == jax.tree.structure(aq)
    batch = {"tokens": jnp.ones((2, 32), jnp.int32)}
    h, _, _ = M.forward(cfg, p, a, batch)
    hq, _, _ = M.forward(qcfg, pq, aq, batch)
    # int4 from-scratch weights: loose bound, but same scale & finite
    assert bool(jnp.isfinite(hq.astype(jnp.float32)).all())
    r = float(jnp.abs(hq.astype(jnp.float32) - h.astype(jnp.float32)).mean()
              / (jnp.abs(h.astype(jnp.float32)).mean() + 1e-6))
    assert r < 0.5


def test_quantized_train_step_runs():
    _, qcfg = _qcfg("stablelm-3b")
    from repro.optim import adamw
    pq = M.init_params(qcfg, KEY)
    aq = M.init_adapters(qcfg, KEY, pq)
    st = adamw.init(aq)
    step = jax.jit(M.make_train_step(qcfg, n_microbatches=1, lr=1e-3))
    batch = {"tokens": jnp.ones((2, 32), jnp.int32),
             "labels": jnp.ones((2, 32), jnp.int32)}
    a1, st1, m = step(pq, aq, st, batch)
    assert np.isfinite(float(m["loss"])) and float(m["grad_norm"]) > 0


def test_moe_sort_ranking_matches_cumsum():
    """Sort-based position-in-expert ≡ the one-hot cumsum reference
    (first-come-first-served per expert)."""
    rng = np.random.default_rng(0)
    E, TK = 7, 200
    flat_e = jnp.asarray(rng.integers(0, E, TK))
    # reference: cumsum over one-hot
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos_ref = jnp.cumsum(onehot, axis=0) - 1
    pos_ref = jnp.take_along_axis(pos_ref, flat_e[:, None], axis=1)[:, 0]
    # sort-based (ffn.moe logic)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))
    pos_sorted = jnp.arange(TK) - starts[sorted_e]
    pos = jnp.zeros((TK,), jnp.int32).at[order].set(pos_sorted)
    np.testing.assert_array_equal(np.asarray(pos), np.asarray(pos_ref))


@pytest.mark.parametrize("name", ["kimi-k2-1t-a32b", "jamba-1.5-large-398b"])
def test_moe_forward_capacity_drop(name):
    """MoE keeps ≤ capacity tokens per expert and stays finite."""
    cfg = get(name + "-smoke")
    p = M.init_params(cfg, KEY)
    a = M.init_adapters(cfg, KEY, p)
    batch = {"tokens": jax.random.randint(KEY, (2, 64), 4,
                                          cfg.vocab_size - 4)}
    h, bal, _ = M.forward(cfg, p, a, batch)
    assert bool(jnp.isfinite(h.astype(jnp.float32)).all())
    assert float(bal) > 0      # balance loss well-defined
