"""Core LLM-QFL: regulation law, selection, termination, distillation,
and the full Algorithm-1 integration (QFL vs LLM-QFL)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
# resolves to real hypothesis when installed, else the deterministic
# vendored fallback conftest.py registers in sys.modules
from hypothesis import given, settings, strategies as st

from repro.core import distill, regulation, selection
from repro.core.termination import TerminationCriterion
from repro.core.orchestrator import RunConfig, run_experiment
from repro.data.tasks import build_task


# --- regulation ---------------------------------------------------------------
def test_regulate_adaptive_is_ratio():
    # QNN behind the LLM by 2×: maxiter doubles (ratio·maxiter), capped
    assert regulation.regulate(10, 2.0, 1.0, variant="adaptive") == 20
    assert regulation.regulate(80, 2.0, 1.0, variant="adaptive",
                               cap=100) == 100


def test_regulate_noop_when_ahead():
    # Alg. 1 line 12: only boost when LLM_l < QNN_l
    for v in regulation.VARIANTS:
        assert regulation.regulate(10, 0.5, 1.0, variant=v) == 10


@given(st.integers(1, 80), st.floats(0.01, 10.0), st.floats(0.01, 10.0))
@settings(max_examples=60, deadline=None)
def test_regulate_monotone_in_ratio(maxiter, qnn_l, llm_l):
    """Regulated maxiter is monotone non-decreasing in the loss ratio."""
    for v in regulation.VARIANTS:
        lo = regulation.regulate(maxiter, qnn_l, llm_l, variant=v)
        hi = regulation.regulate(maxiter, qnn_l * 1.5, llm_l, variant=v)
        assert hi >= lo
        assert 1 <= lo <= 100 and 1 <= hi <= 100


def test_regulate_nonfinite_qnn_loss_holds_budget():
    """A diverged client (NaN/inf loss) must not crash regulation — the
    current budget is held, clamped to [min_iter, cap]."""
    for bad in (float("nan"), float("inf"), float("-inf")):
        for v in regulation.VARIANTS:
            assert regulation.regulate(10, bad, 1.0, variant=v) == 10
    assert regulation.regulate(200, float("nan"), 1.0, cap=100) == 100
    assert regulation.regulate(0, float("inf"), 1.0, min_iter=1) == 1


def test_regulate_variants_distinct():
    vals = {v: regulation.regulate(10, 3.0, 1.0, variant=v)
            for v in regulation.VARIANTS}
    assert vals["adaptive"] == 30
    assert vals["incremental"] == 16          # 10 + 2·3
    assert vals["logarithmic"] == 21          # 10·(1+ln3)
    assert vals["dynamic"] == 20              # 0.5·10 + 0.5·30


# --- selection -------------------------------------------------------------------
def test_select_aligned_picks_closest():
    losses = [0.5, 0.9, 0.52, 1.5]
    sel = selection.select_aligned(losses, 0.5, 0.5)
    assert sel == [0, 2]


def test_select_always_nonempty():
    assert selection.select_aligned([1.0], 0.0, 0.01) == [0]


@given(st.lists(st.floats(0.0, 5.0), min_size=3, max_size=20),
       st.floats(0.0, 5.0), st.floats(0.1, 0.9))
@settings(max_examples=60, deadline=None)
def test_selection_variance_reduction(losses, server, frac):
    """Cor. VI.8.2: Var over the aligned subset ≤ Var over all."""
    sel = selection.select_aligned(losses, server, frac)
    v = selection.selection_variance(losses, server, sel)
    assert v["var_selected"] <= v["var_all"] + 1e-12


def test_selection_diverged_clients_sort_last():
    """A NaN/inf client loss is maximally misaligned: never selected
    while finite candidates remain, and it must not poison the
    RoundRecord variance stats with NaN."""
    losses = [0.6, float("nan"), 0.5, float("inf")]
    sel = selection.select_aligned(losses, 0.5, 0.5)
    assert sel == [0, 2]
    v = selection.selection_variance(losses, 0.5, sel)
    assert np.isfinite(v["var_all"]) and np.isfinite(v["var_selected"])
    assert v["var_selected"] <= v["var_all"] + 1e-12
    # variance over finite entries only: [0.1², 0²] for both stats here
    assert v["var_all"] == pytest.approx(
        np.mean([0.1 ** 2, 0.0 ** 2]), abs=1e-12)


def test_selection_all_diverged_is_safe():
    losses = [float("nan"), float("inf")]
    sel = selection.select_aligned(losses, 1.0, 0.5)
    assert sel == [0]                      # stable, non-empty
    v = selection.selection_variance(losses, 1.0, sel)
    assert v["var_all"] == 0.0 and v["var_selected"] == 0.0


def test_selection_nan_server_loss_is_safe():
    v = selection.selection_variance([0.5, 0.6], float("nan"), [0])
    assert np.isfinite(v["var_all"]) and np.isfinite(v["var_selected"])


# --- termination ------------------------------------------------------------------
def test_termination_on_plateau():
    t = TerminationCriterion(epsilon=1e-2, t_max=100)
    assert not t.update(1.0, 1)
    assert not t.update(0.5, 2)
    assert t.update(0.4999, 3)          # rel. improvement 2e-4 < 1e-2


def test_termination_zero_loss_plateau():
    """Exactly-zero server loss must still terminate: Δ = 0 on a zero
    plateau is converged, not an un-checkable division."""
    t = TerminationCriterion(epsilon=1e-3, t_max=100)
    assert not t.update(0.0, 1)
    assert t.update(0.0, 2)
    # a fresh drop to 0 is progress, the following plateau converges
    t2 = TerminationCriterion(epsilon=1e-3, t_max=100)
    assert not t2.update(1.0, 1)
    assert not t2.update(0.0, 2)
    assert t2.update(0.0, 3)


def test_termination_tmax():
    t = TerminationCriterion(epsilon=1e-9, t_max=3)
    assert not t.update(3.0, 1)
    assert not t.update(2.0, 2)
    assert t.update(1.0, 3)


# --- distillation -------------------------------------------------------------------
def test_kl_zero_iff_equal():
    p = jnp.array([[0.3, 0.7], [0.9, 0.1]])
    assert float(distill.kl_divergence(p, p)) == pytest.approx(0.0, abs=1e-6)
    q = jnp.array([[0.7, 0.3], [0.1, 0.9]])
    assert float(distill.kl_divergence(p, q)) > 0.1


def test_client_objective_composition():
    """objective = F + λ·KL + µ·prox: each term contributes."""
    fwd = lambda th, X: jnp.tile(jax.nn.softmax(th[:2])[None], (X.shape[0], 1))
    base = lambda th: jnp.sum(th ** 2)
    X = jnp.zeros((4, 4))
    teacher = jnp.tile(jnp.array([[0.9, 0.1]]), (4, 1))
    tg = np.array([1.0, 1.0])
    obj = distill.make_client_objective(base, fwd, X, teacher, tg,
                                        lam=1.0, mu=1.0)
    th = np.array([0.0, 0.0])
    val = obj(th)
    # base=0, KL(0.9/0.1‖0.5/0.5)>0, prox=mean((0-1)^2)=1
    assert val > 1.0


# --- integration: Algorithm 1 -------------------------------------------------------
@pytest.fixture(scope="module")
def small_task():
    return build_task("genomic", n_clients=3, train_size=90, test_size=45,
                      val_size=30, seed=5)


def test_qfl_baseline_runs(small_task):
    res = run_experiment(small_task, method="qfl", n_rounds=3, maxiter0=5,
                         early_stop=False)
    assert len(res.rounds) == 3
    # plain QFL never regulates
    for r in res.rounds:
        assert r.maxiters == [5, 5, 5]
        assert r.selected == [0, 1, 2]
    assert all(np.isfinite(r.server_loss) for r in res.rounds)


def test_llm_qfl_regulates_and_improves(small_task):
    res = run_experiment(small_task, method="llm-qfl", n_rounds=4,
                         maxiter0=5, llm_steps=10, early_stop=False, seed=2)
    assert res.llm_losses and all(np.isfinite(l) for l in res.llm_losses)
    # regulation must have engaged for at least one device after round 1
    assert any(m != 5 for r in res.rounds[1:] for m in r.maxiters)
    # loss should not blow up; final ≤ first (stochastic but reliable here)
    assert res.rounds[-1].server_loss <= res.rounds[0].server_loss * 1.5


def test_llm_qfl_selected_subsets(small_task):
    res = run_experiment(small_task, method="llm-qfl", select_frac=0.34,
                         n_rounds=3, maxiter0=5, llm_steps=8,
                         early_stop=False)
    for r in res.rounds:
        assert len(r.selected) == 1
        assert r.var_selected <= r.var_all + 1e-12


def test_early_termination_short_circuits(small_task):
    res = run_experiment(small_task, method="llm-qfl", n_rounds=50,
                         maxiter0=5, llm_steps=8, epsilon=0.9)
    assert len(res.rounds) < 50
    assert res.terminated_early


def test_tweets_qcnn_path():
    task = build_task("tweets", n_clients=3, train_size=90, test_size=30,
                      val_size=30, seed=7)
    res = run_experiment(task, method="llm-qfl", n_rounds=2, maxiter0=4,
                         llm_steps=8, early_stop=False)
    assert len(res.rounds) == 2
    assert np.isfinite(res.rounds[-1].server_loss)
