"""Batched LLM fine-tuning engine: draw-for-draw sequential parity, the
ragged-pad contract, the on-device FedAvg/distill algebra, and 'clients'
mesh parity for the LLM stage (alongside ``test_client_sharding.py``).

The contract under test (``core/llm_client.py`` docstring): every draw
derives from ``llm_key(llm_root(seed), client, step)`` and
``sample_minibatch_idx`` is a pure function of (key, shard size), so the
batched engine's vmapped draws are bitwise the sequential wrapper's —
fine-tuned adapters and downstream evals then agree to fp32
arithmetic-order noise only.
"""
import json
import os
import re
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import llm_client as llmc
from repro.core.batched_llm import BatchedLLMEngine
from repro.core.llm_client import run_sequential_stage, task_llm_config
from repro.data.tasks import build_task
from repro.distributed import sharding as shd
from repro.models import model as M
from repro.peft import lora as lora_mod

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

STEPS = 6


@pytest.fixture(scope="module")
def task():
    # n=17/16/16 across 3 clients: ragged example counts exercise the
    # (C, Nmax, L) pad
    return build_task("genomic", n_clients=3, train_size=49, test_size=16,
                      val_size=16, seed=3)


@pytest.fixture(scope="module")
def setup(task):
    cfg = task_llm_config("tiny-llm", task.vocab_size, task.llm_seq_len)
    base = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, base


@pytest.fixture(scope="module")
def seq_ref(task, setup):
    cfg, base = setup
    return run_sequential_stage(task, cfg, base, seed=11, steps=STEPS)


@pytest.fixture(scope="module")
def bat_ref(task, setup):
    cfg, base = setup
    eng = BatchedLLMEngine(task, cfg, base, seed=11, steps=STEPS)
    return eng, eng.run()


# --- the key contract: draws are bitwise identical across engines ------------
def test_minibatch_draws_bitwise_vmapped_vs_sequential():
    root = llmc.llm_root(5)
    ns = jnp.asarray([17, 16, 3])          # ragged shard sizes, one < bs
    bs = 16
    step = 4
    seq = [llmc.sample_minibatch_idx(llmc.llm_key(root, c, step),
                                     int(ns[c]), bs) for c in range(3)]
    ckeys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        root, jnp.arange(3))
    keys = jax.vmap(jax.random.fold_in, in_axes=(0, None))(ckeys, step)
    bat = jax.vmap(llmc.sample_minibatch_idx, in_axes=(0, 0, None))(
        keys, ns, bs)
    for c in range(3):
        np.testing.assert_array_equal(np.asarray(bat[c]),
                                      np.asarray(seq[c]))
        assert int(bat[c].max()) < int(ns[c])


def test_stacked_adapter_init_bitwise(task, setup):
    """vmapped init over contract keys == per-client LLMClient init."""
    cfg, base = setup
    root = llmc.llm_root(11)
    cl = llmc.LLMClient(cfg, base, root, client_id=1,
                        n_labels=task.n_classes)
    ikeys = jax.vmap(llmc.llm_key, in_axes=(None, 0, None))(
        root, jnp.arange(3), llmc.LLM_INIT_STEP)
    stacked = jax.vmap(lambda k: M.init_adapters(cfg, k, base))(ikeys)
    for a, b in zip(jax.tree.leaves(cl.adapters),
                    jax.tree.leaves(stacked)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b[1]))


# --- stage parity: sequential reference vs one fused program -----------------
def test_stage_parity_losses_f1_teacher(task, seq_ref, bat_ref):
    _, seq_losses, seq_f1, seq_teachers = seq_ref
    _, out = bat_ref
    np.testing.assert_allclose(out.losses, seq_losses, atol=5e-4)
    # identical draws → identical predictions; f1 could only move if an
    # argmax near-tie flips on ~1e-6 logit noise (would jump by >= 1/n)
    np.testing.assert_allclose(out.f1, seq_f1, atol=0.05)
    for i, ts in enumerate(seq_teachers):
        got = out.teacher[i, : task.clients[i].n]
        np.testing.assert_allclose(got, np.asarray(ts), atol=5e-4)
        np.testing.assert_allclose(got.sum(1), 1.0, atol=1e-5)


def test_stage_parity_final_adapters(task, seq_ref, bat_ref):
    clients, *_ = seq_ref
    eng, _ = bat_ref
    for i, cl in enumerate(clients):
        for a, b in zip(jax.tree.leaves(cl.adapters),
                        jax.tree.leaves(eng.adapters)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b[i]),
                                       atol=1e-3)


def test_refresh_continues_global_step_stream(task, setup):
    """A second run() is a *refresh*, not a replay: the contract's step
    index is global, so two runs of S steps draw-for-draw match the
    sequential path doing fine_tune → distill → fine_tune → distill
    with its own continuing step counter."""
    cfg, base = setup
    eng = BatchedLLMEngine(task, cfg, base, seed=7, steps=3)
    eng.run()
    out2 = eng.run()

    root = llmc.llm_root(7)
    clients = []
    for i in range(task.n_clients):
        cl = llmc.LLMClient(cfg, base, root, client_id=i,
                            n_labels=task.n_classes)
        cl.fine_tune(task.clients[i].llm_batch, steps=3)
        clients.append(cl)
    llmc.distill_to_global(clients, task.weights)
    for i, cl in enumerate(clients):
        assert cl._n_steps == 3
        cl.fine_tune(task.clients[i].llm_batch, steps=3)  # steps 3..5
    llmc.distill_to_global(clients, task.weights)
    seq_losses = [cl.eval_loss(task.clients[i].llm_batch)
                  for i, cl in enumerate(clients)]
    np.testing.assert_allclose(out2.losses, seq_losses, atol=5e-4)


def test_fine_tune_learns_batched(task, seq_ref, bat_ref):
    """The fused stage trains, not just runs: post-distill eval loss is
    far below chance NLL and F1 is far above chance."""
    _, out = bat_ref
    chance = np.log(task.n_classes)
    assert all(l < 0.8 * chance for l in out.losses)
    assert all(f > 0.6 for f in out.f1)
    assert np.all(np.isfinite(out.final_train_loss))


# --- ragged client pad is inert ----------------------------------------------
def test_client_padding_rows_inert(task, setup):
    """pad_to adds inert clients (zero rowmask/weight, PAD shards): real
    clients' outputs match the unpadded run and padded rows train to
    exactly nothing (zero CE grads → zero AdamW updates)."""
    cfg, base = setup
    plain = BatchedLLMEngine(task, cfg, base, seed=11, steps=STEPS)
    padded = BatchedLLMEngine(task, cfg, base, seed=11, steps=STEPS,
                              pad_to=5)
    init_pad = jax.tree.map(lambda x: np.asarray(x[3:]), padded.adapters)
    a = plain.run()
    b = padded.run()
    np.testing.assert_allclose(b.losses, a.losses, atol=1e-5)
    np.testing.assert_allclose(b.f1, a.f1, atol=0.05)
    np.testing.assert_allclose(b.teacher, a.teacher, atol=1e-5)
    # padding clients' adapters moved only by the distill blend toward
    # a_g, never by training: a_pad_final == (1-ρ)·a_pad_init + ρ·a_g
    rho = 0.25
    for g, p0, pf in zip(jax.tree.leaves(padded.a_g),
                         jax.tree.leaves(init_pad),
                         jax.tree.leaves(padded.adapters)):
        want = (1 - rho) * p0 + rho * np.asarray(g)[None]
        np.testing.assert_allclose(np.asarray(pf[3:]), want, atol=1e-6)


# --- on-device FedAvg / distill algebra --------------------------------------
def test_weighted_average_stacked_matches_fedavg():
    rng = np.random.default_rng(0)
    leaves = [{"a": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)}
              for _ in range(3)]
    w = [3.0, 1.0, 2.0]
    host = llmc.fedavg_adapters(leaves, w)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)
    dev = lora_mod.weighted_average_stacked(stacked, jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(dev["a"]),
                               np.asarray(host["a"]), atol=1e-6)
    # zero-weight (padding) clients contribute nothing
    w_pad = jnp.asarray([3.0, 1.0, 2.0, 0.0])
    stacked4 = jax.tree.map(
        lambda s: jnp.concatenate([s, 1e6 * jnp.ones_like(s[:1])]),
        stacked)
    dev4 = lora_mod.weighted_average_stacked(stacked4, w_pad)
    np.testing.assert_allclose(np.asarray(dev4["a"]),
                               np.asarray(host["a"]), atol=1e-6)


def test_blend_adapters_stacked_broadcast():
    a = {"x": jnp.arange(6, dtype=jnp.float32).reshape(3, 2)}
    g = {"x": jnp.ones((2,), jnp.float32)}
    out = lora_mod.blend_adapters(a, g, rho=0.5)
    np.testing.assert_allclose(np.asarray(out["x"]),
                               0.5 * np.asarray(a["x"]) + 0.5)


# --- sharding helpers for adapter pytrees ------------------------------------
def test_client_tree_specs_strict():
    tree = {"a": np.zeros((4, 2, 3)), "step": np.zeros((4,))}
    specs = shd.client_tree_specs(tree, 4)
    assert specs["a"] == jax.sharding.PartitionSpec("clients", None, None)
    assert specs["step"] == jax.sharding.PartitionSpec("clients")
    with pytest.raises(ValueError, match="vmap"):
        shd.client_tree_specs({"a": np.zeros((3, 2))}, 4)
    with pytest.raises(ValueError, match="vmap"):
        shd.client_tree_specs({"a": np.zeros(())}, 4)


def test_put_replicated_pytree():
    mesh = shd.client_mesh(1)
    tree = {"w": np.ones((3, 2)), "g": (np.zeros((5,)),)}
    out = shd.put_replicated(mesh, tree)
    assert out["w"].sharding.spec == jax.sharding.PartitionSpec()
    np.testing.assert_array_equal(np.asarray(out["g"][0]), tree["g"][0])


# --- 'clients' mesh parity (CI runs this under 8 forced host devices) --------
@multi_device
def test_sharded_llm_stage_parity():
    """8-way mesh == single device for the fused LLM stage, ragged C=3
    (5 inert padding clients) included."""
    task = build_task("genomic", n_clients=3, train_size=48, test_size=16,
                      val_size=16, seed=9)
    cfg = task_llm_config("tiny-llm", task.vocab_size, task.llm_seq_len)
    base = M.init_params(cfg, jax.random.PRNGKey(1), dtype=jnp.float32)
    one = BatchedLLMEngine(task, cfg, base, seed=4, steps=4).run()
    shard = BatchedLLMEngine(task, cfg, base, seed=4, steps=4,
                             n_devices=8).run()
    np.testing.assert_allclose(shard.losses, one.losses, atol=1e-4)
    np.testing.assert_allclose(shard.f1, one.f1, atol=0.05)
    np.testing.assert_allclose(shard.teacher, one.teacher, atol=1e-4)


@multi_device
def test_sharded_llm_qfl_run_parity():
    """Full llm-qfl round trip with the LLM stage sharded: regulation
    budgets and selection survive the mesh."""
    from repro.core.orchestrator import run_experiment
    task = build_task("genomic", n_clients=8, train_size=64, test_size=24,
                      val_size=24, seed=5)
    kw = dict(method="llm-qfl", optimizer="nelder-mead", n_rounds=2,
              maxiter0=3, llm_steps=4, early_stop=False, seed=2,
              engine="batched")
    one = run_experiment(task, **kw)
    shard = run_experiment(task, n_devices=8, **kw)
    np.testing.assert_allclose(shard.llm_losses, one.llm_losses,
                               atol=1e-4)
    assert shard.series("maxiters") == one.series("maxiters")
    assert shard.series("selected") == one.series("selected")
    np.testing.assert_allclose(shard.series("server_loss"),
                               one.series("server_loss"), atol=1e-4)


# --- subprocess: sharded-LLM coverage from a single-device tier-1 run --------
_CHILD = r"""
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.data.tasks import build_task
from repro.core.batched_llm import BatchedLLMEngine
from repro.core.llm_client import task_llm_config
from repro.models import model as M

task = build_task("genomic", n_clients=3, train_size=36, test_size=12,
                  val_size=12, seed=9)
cfg = task_llm_config("tiny-llm", task.vocab_size, task.llm_seq_len)
base = M.init_params(cfg, jax.random.PRNGKey(1), dtype=jnp.float32)
one = BatchedLLMEngine(task, cfg, base, seed=4, steps=3).run()
shard = BatchedLLMEngine(task, cfg, base, seed=4, steps=3,
                         n_devices=8).run()
print("RESULT:" + json.dumps({
    "dloss": float(np.abs(shard.losses - one.losses).max()),
    "dteacher": float(np.abs(shard.teacher - one.teacher).max()),
}))
"""


@pytest.mark.skipif(
    len(jax.devices()) >= 8,
    reason="a real mesh is visible — the in-process parity tests above "
           "cover this; don't pay the heavy child interpreter twice")
def test_sharded_llm_parity_forced_host_devices():
    """Force 8 host devices in a fresh interpreter and require the
    sharded LLM stage to match the single-device stage, padding (ragged
    C=3 on an 8-way mesh) included."""
    env = dict(os.environ)
    flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                   env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = (flags
                        + " --xla_force_host_platform_device_count=8"
                        ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULT:")][-1]
    got = json.loads(line[len("RESULT:"):])
    assert got["dloss"] <= 1e-4, got
    assert got["dteacher"] <= 1e-4, got
