"""Sharding rules: spec construction, axis filtering, divisibility fitting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get
from repro.distributed import sharding as shd
from repro.models import model as M


def test_fit_divisibility_drops_bad_axes():
    # vocab 51866 is not divisible by a 16-way axis → dropped
    spec = shd._fit_divisibility(P("model", "data"), (51866, 1280),
                                 {"model": 16, "data": 16})
    assert spec == P(None, "data")


def test_fit_divisibility_tuple_axes():
    # (pod, data) = 2·16 = 32 divides 64; keeps tuple
    spec = shd._fit_divisibility(P(("pod", "data")), (64,),
                                 {"pod": 2, "data": 16})
    assert spec == P(("pod", "data"))
    # 48 % 32 != 0 but 48 % 2 == 0 → keeps only 'pod'
    spec = shd._fit_divisibility(P(("pod", "data")), (48,),
                                 {"pod": 2, "data": 16})
    assert spec == P("pod")


def test_filter_axes_removes_missing():
    spec = shd._filter_axes(P("pod", "model"), ("data", "model"))
    assert spec == P(None, "model")


def test_param_specs_cover_tree():
    cfg = get("stablelm-3b-smoke")
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    specs = shd.param_specs(p, ("data", "model"))
    flat_p = jax.tree.leaves(p)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    assert len(flat_p) == len(flat_s)
    for x, s in zip(flat_p, flat_s):
        assert isinstance(s, P)
        # spec rank ≤ array rank
        assert len(s) <= x.ndim


def test_param_specs_embed_rule():
    cfg = get("stablelm-3b-smoke")
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    specs = shd.param_specs(p, ("data", "model"),
                            {"data": 2, "model": 2})
    assert specs["embed"] == P("model", "data")


def test_lora_specs_follow_targets():
    spec = shd._leaf_spec("wq_lora_a", (512, 16), False)
    assert spec == P("data", None)
    spec = shd._leaf_spec("wq_lora_b", (16, 512), False)
    assert spec == P(None, "model")


def test_batch_specs():
    batch = {"tokens": jnp.zeros((8, 16), jnp.int32),
             "pos": jnp.zeros((), jnp.int32)}
    specs = shd.batch_specs(batch, ("pod", "data", "model"))
    assert specs["tokens"] == P(("pod", "data"), None)
    assert specs["pos"] == P()


def test_cache_specs_divisibility():
    cache = (jnp.zeros((4, 128, 32768, 8, 64)),   # (G,B,S,KH,D)
             jnp.zeros((4, 128, 1500, 8, 64)))    # cross-kv, S=1500
    specs = shd.cache_specs(cache, ("data", "model"), 128,
                            {"data": 16, "model": 16})
    assert specs[0] == P(None, "data", "model", None, None)
    # 1500 not divisible by 16 → seq axis unsharded
    assert specs[1] == P(None, "data", None, None, None)


def test_constrain_noop_outside_mesh():
    x = jnp.ones((4, 4))
    y = shd.constrain(x, P("data", None))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
