"""Circuit tape compiler: tape-vs-eager statevector equality (VQC + QCNN)
and the batched gate-apply kernel contract (jnp path = Pallas = oracle)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.quantum import circuits as C, qnn, statevector as sv, tape as T

KEY = jax.random.PRNGKey(11)


def _batch(n=6):
    return jax.random.uniform(KEY, (n, 4), jnp.float32, 0, np.pi)


# --- tape vs eager circuits --------------------------------------------------
def test_vqc_tape_statevector_equality():
    spec = qnn.QNNSpec("vqc", n_qubits=4)
    th = spec.init_params(jax.random.PRNGKey(1))
    cq = T.compile_qnn(spec)
    X = _batch()
    psi_tape = T.run_tape(cq.tape, T.tape_angles(cq.tape, X, th))
    psi_eager = jnp.stack([
        C.real_amplitudes(C.zz_feature_map(x, reps=spec.fm_reps), th,
                          reps=spec.ansatz_reps).reshape(-1) for x in X])
    np.testing.assert_allclose(np.asarray(psi_tape), np.asarray(psi_eager),
                               atol=1e-6)


def test_qcnn_tape_statevector_equality_and_readout():
    spec = qnn.QNNSpec("qcnn", n_qubits=4)
    th = spec.init_params(jax.random.PRNGKey(2))
    cq = T.compile_qnn(spec)
    X = _batch()
    psi_tape = T.run_tape(cq.tape, T.tape_angles(cq.tape, X, th))
    eager = [C.qcnn(C.zz_feature_map(x, reps=spec.fm_reps), th) for x in X]
    psi_eager = jnp.stack([p.reshape(-1) for p, _ in eager])
    np.testing.assert_allclose(np.asarray(psi_tape), np.asarray(psi_eager),
                               atol=1e-6)
    assert cq.readout == eager[0][1]


@pytest.mark.parametrize("kind", ["vqc", "qcnn"])
def test_tape_forward_matches_qnn_forward(kind):
    spec = qnn.QNNSpec(kind, n_qubits=4)
    th = spec.init_params(jax.random.PRNGKey(3))
    X = _batch(8)
    p_tape = T.make_tape_forward(spec)(th, X)
    p_eager = qnn.make_forward(spec)(th, X)
    assert p_tape.shape == p_eager.shape == (8, 2)
    np.testing.assert_allclose(np.asarray(p_tape), np.asarray(p_eager),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(p_tape.sum(1)), 1.0, atol=1e-5)


def test_tape_angles_sources():
    """Constant, feature-linear, ZZ, and theta angle sources resolve."""
    tb = T.TapeBuilder(2)
    tb.rz_const(0, 0.5)
    tb.p_linear(0, 1)
    tb.p_zz(1, 0, 1)
    tb.ry_theta(1, 0)
    tape = tb.build()
    X = jnp.array([[1.0, 2.0]], jnp.float32)
    theta = jnp.array([0.25], jnp.float32)
    ang = np.asarray(T.tape_angles(tape, X, theta))[0]
    assert ang[0] == pytest.approx(0.5)
    assert ang[1] == pytest.approx(4.0)          # 2·x[1]
    assert ang[2] == pytest.approx(2 * (np.pi - 1) * (np.pi - 2), rel=1e-6)
    assert ang[3] == pytest.approx(0.25)


# --- batched gate apply: jnp path = Pallas kernel = oracle -------------------
def test_gate_apply_pallas_matches_oracle_and_jnp():
    n = 4
    B, N = 8, 1 << n
    k1, k2, k3 = jax.random.split(KEY, 3)
    psi = (jax.random.normal(k1, (B, N)) +
           1j * jax.random.normal(k2, (B, N))).astype(sv.CDTYPE)
    g = T._mat_ry(jax.random.uniform(k3, (B,), jnp.float32, -3, 3))
    for target, control in [(0, -1), (2, -1), (1, 3), (3, 0)]:
        idx0, idx1, cmask = T.pair_indices(target, control, n)
        want = ref.statevector_gate(
            jnp.real(psi), jnp.imag(psi), jnp.real(g), jnp.imag(g),
            idx0, idx1, cmask.astype(jnp.float32))
        got = ops.statevector_gate(
            jnp.real(psi), jnp.imag(psi), jnp.real(g), jnp.imag(g),
            idx0, idx1, cmask.astype(jnp.float32))
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                                   atol=1e-6)
        via_jnp = T.jnp_gate_apply(psi, g, jnp.int32(target),
                                   jnp.int32(control), n)
        np.testing.assert_allclose(np.asarray(jnp.real(via_jnp)),
                                   np.asarray(want[0]), atol=1e-6)


def test_run_tape_pallas_path_matches_jnp_path():
    spec = qnn.QNNSpec("vqc", n_qubits=4)
    th = spec.init_params(jax.random.PRNGKey(4))
    cq = T.compile_qnn(spec)
    X = _batch(4)
    ang = T.tape_angles(cq.tape, X, th)
    psi_jnp = T.run_tape(cq.tape, ang)
    psi_pl = T.run_tape(cq.tape, ang, gate_apply=T.pallas_gate_apply)
    np.testing.assert_allclose(np.asarray(psi_pl), np.asarray(psi_jnp),
                               atol=1e-6)


def test_gate_apply_controlled_identity_on_zero_control():
    """CX with control bit 0 must leave amplitudes untouched."""
    n = 2
    psi = sv.zero_state(n).reshape(1, -1)        # |00>: control bit is 0
    g = T._mat_x(jnp.zeros((1,), jnp.float32))
    out = T.jnp_gate_apply(psi, g, jnp.int32(1), jnp.int32(0), n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(psi), atol=1e-7)
