"""Cross-engine parity of the fused round loop (``core/fused_rounds``).

The fused program runs R federated rounds as one jitted ``lax.scan``;
these tests pin it to the host references round-for-round at fixed
seeds.  The parity split (module docstring of ``fused_rounds``):

  - **exact**: every quantized quantity — selected sets, regulated
    budgets, cumulative eval counts, cohort / dropout draws, and the
    termination round.  These go through integer or key-derivation
    paths with no floating-point headroom.
  - **f32 tolerance (~1e-5)**: θ_g, client losses, server metrics —
    the host aggregates and reports in float64 while the fused scan is
    float32 end to end.  On finite-shot backends with equal client
    shards the reported losses are additionally *bitwise* (same padded
    draw shape, same ``REPORT_EVAL_SLOT`` key).

Property tests (hypothesis, or the deterministic conftest fallback)
pin the traceable twins — ``select_topk_mask`` / ``regulate_batched`` /
``termination_step`` — to ``selection.select_aligned`` /
``regulation.regulate`` / ``TerminationCriterion`` on adversarial
inputs (ties, NaN/inf, knife-edge fractions), drawing floats from
binary-fraction grids so f32 and f64 order identically.

Mesh coverage mirrors ``test_client_sharding.py``: in-process parity on
a real >= 8 device mesh (CI's forced-host-device step) plus a subprocess
child that forces 8 host devices so single-device tier-1 runs still
exercise the sharded population path.
"""
import functools
import json
import os
import re
import subprocess
import sys

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import regulation as regulation_mod
from repro.core import selection
from repro.core.fused_rounds import (FusedRoundDriver, regulate_batched,
                                     select_topk_mask, termination_step)
from repro.core.orchestrator import run_experiment
from repro.core.termination import TerminationCriterion
from repro.quantum import backends as backend_mod
from repro.quantum import qnn

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@functools.lru_cache(maxsize=None)
def _task(n_clients, train, test, val, seed):
    from repro.data.tasks import build_task
    return build_task("genomic", n_clients=n_clients, train_size=train,
                      test_size=test, val_size=val, seed=seed)


# ---------------------------------------------------------------------------
# orchestrator-level parity: rounds="fused" vs rounds="host", same config
# ---------------------------------------------------------------------------
def _pair(task, **kw):
    host = run_experiment(task, engine="batched", rounds="host", **kw)
    fused = run_experiment(task, engine="batched", rounds="fused", **kw)
    return host, fused


def _assert_round_parity(host, fused, atol=1e-5):
    assert len(fused.rounds) == len(host.rounds)
    assert fused.terminated_early == host.terminated_early
    # quantized quantities: exact, every round
    assert fused.series("selected") == host.series("selected")
    assert fused.series("maxiters") == host.series("maxiters")
    assert fused.series("cum_evals") == host.series("cum_evals")
    for fr, hr in zip(fused.rounds, host.rounds):
        np.testing.assert_allclose(fr.client_losses, hr.client_losses,
                                   atol=atol)
        np.testing.assert_allclose(fr.ratios, hr.ratios, rtol=1e-5)
        assert abs(fr.server_loss - hr.server_loss) <= atol
        assert abs(fr.server_val_acc - hr.server_val_acc) <= atol
        assert abs(fr.server_test_acc - hr.server_test_acc) <= atol
        np.testing.assert_allclose(fr.comm_time_s, hr.comm_time_s,
                                   rtol=1e-5, atol=1e-12)
    np.testing.assert_allclose(fused.theta_g, host.theta_g, atol=2e-6)


def test_parity_qfl_spsa_noiseless():
    """R=6 fused == host, round for round (SPSA, exact backend)."""
    task = _task(3, 90, 45, 30, 5)
    host, fused = _pair(task, method="qfl", optimizer="spsa", n_rounds=6,
                        maxiter0=3, early_stop=False, seed=3)
    assert len(fused.rounds) == 6
    _assert_round_parity(host, fused)


def test_parity_qfl_spsa_shots():
    """Finite-shot SPSA: draws follow the eval_key contract, so eval
    counts and budgets stay exact and the two reporting paths — the
    fused scan's in-carry report vs the orchestrator's per-client
    ``_nll`` loop — agree to f32 (the host trains from a float64 θ_g,
    so trained thetas differ by ulps before the report draw; the
    bitwise reporting pin lives in the population parity test, where
    both paths share the f32 local phase)."""
    task = _task(3, 90, 45, 30, 5)
    host, fused = _pair(task, method="qfl", optimizer="spsa", n_rounds=6,
                        maxiter0=3, early_stop=False, backend="fake",
                        seed=3)
    _assert_round_parity(host, fused)


def test_parity_qfl_nm_noiseless():
    """Nelder–Mead's branch ladder survives the fusion: per-iteration
    branch choices (hence eval counts) are quantized and stay exact."""
    task = _task(3, 90, 45, 30, 5)
    host, fused = _pair(task, method="qfl", optimizer="nelder-mead",
                        n_rounds=6, maxiter0=3, early_stop=False, seed=3)
    _assert_round_parity(host, fused)


def test_parity_llmqfl_nm_shots_regulation_selection():
    """The full LLM-QFL path — regulation boosts budgets from round 2,
    alignment selection keeps top-50% — fused vs host, finite shots."""
    task = _task(3, 90, 45, 30, 5)
    kw = dict(method="llm-qfl", optimizer="nelder-mead", backend="fake",
              n_rounds=6, maxiter0=3, maxiter_cap=12, select_frac=0.5,
              llm_steps=4, early_stop=False, seed=3)
    host, fused = _pair(task, **kw)
    _assert_round_parity(host, fused)
    # the interesting machinery actually fired: budgets were regulated
    # above maxiter0 and selection kept k = round(0.5 * 3) = 2 clients
    assert host.rounds[-1].maxiters != [3, 3, 3]
    assert all(len(r.selected) == 2 for r in host.rounds)


def test_parity_early_termination():
    """A huge ε terminates at t=2 (first round with two recorded
    losses): both paths stop at the same round with the same flag."""
    task = _task(3, 90, 45, 30, 5)
    host, fused = _pair(task, method="qfl", optimizer="spsa", n_rounds=6,
                        maxiter0=3, epsilon=10.0, early_stop=True, seed=3)
    assert len(host.rounds) == 2
    assert host.terminated_early and fused.terminated_early
    _assert_round_parity(host, fused)


# ---------------------------------------------------------------------------
# population mode: fused vs the driver's host-reference oracle
# ---------------------------------------------------------------------------
def _pop_driver(backend="exact", dropout=0.0, n_devices=None, c_round=4,
                n_rounds=4):
    task = _task(12, 96, 32, 32, 7)
    spec = qnn.QNNSpec("vqc", n_qubits=4, n_classes=task.n_classes)
    driver = FusedRoundDriver(
        task, spec, backend_mod.get(backend), optimizer="spsa", seed=4,
        maxiter0=3, n_rounds=n_rounds, early_stop=False, c_round=c_round,
        dropout=dropout, n_devices=n_devices)
    theta0 = np.asarray(spec.init_params(jax.random.PRNGKey(11)),
                        np.float64)
    return driver, theta0


def _assert_population_parity(a, b, atol=1e-5):
    for field in ("active", "stop", "cohort", "dropped", "selected",
                  "n_evals", "budgets", "cum_evals", "budgets_final",
                  "cum_evals_final"):
        np.testing.assert_array_equal(getattr(a, field),
                                      getattr(b, field), err_msg=field)
    np.testing.assert_array_equal(np.isnan(a.losses), np.isnan(b.losses))
    np.testing.assert_allclose(a.losses, b.losses, atol=atol)
    np.testing.assert_allclose(a.server_loss, b.server_loss, atol=atol)
    np.testing.assert_allclose(
        a.theta_g, np.asarray(b.theta_g, np.float32), atol=2e-6)


@pytest.mark.parametrize("backend,dropout", [("exact", 0.0),
                                             ("fake", 0.25)])
def test_population_parity_vs_host_reference(backend, dropout):
    """Keyed cohorts + dropout: the fused scan's gather/scatter round
    equals the eager per-round host loop — cohort draws, drop coins,
    budgets, eval spend exactly; losses/θ to f32."""
    driver, theta0 = _pop_driver(backend=backend, dropout=dropout)
    fused = driver.run(theta0)
    host = driver.run_host_reference(theta0)
    _assert_population_parity(fused, host)
    if backend == "fake":
        # both paths train from the same f32 θ and the task's shards
        # are equal (96/12 = 8 each, so the padded report draw shape is
        # each client's own): the in-carry report equals the per-client
        # host transfer **bitwise**, finite shots included
        np.testing.assert_array_equal(fused.losses, host.losses)


def test_subsampling_inertness_and_determinism():
    """Clients outside the round's cohort — and dropped cohort members —
    are bitwise untouched: budgets / cum_evals / last_losses carry
    forward, eval spend is 0, losses NaN, never selected.  A same-seed
    rerun is bitwise identical (sweeps at one seed are comparable)."""
    driver, theta0 = _pop_driver(backend="fake", dropout=0.25)
    out = driver.run(theta0)
    C, R = driver.c_pop, driver.n_rounds

    sampled = set()
    for r in range(R):
        cohort = out.cohort[r]
        effective = cohort[~out.dropped[r]]
        sampled.update(int(c) for c in effective)
        # non-cohort rows: identical to the previous round's carry
        outside = np.setdiff1d(np.arange(C), cohort)
        prev_b = out.budgets[r - 1] if r else np.full(C, 3, np.int32)
        prev_c = out.cum_evals[r - 1] if r else np.zeros(C, np.int32)
        np.testing.assert_array_equal(out.budgets[r][outside],
                                      prev_b[outside])
        np.testing.assert_array_equal(out.cum_evals[r][outside],
                                      prev_c[outside])
        # dropped members: zero spend, NaN report, never selected,
        # carries held
        for p in np.nonzero(out.dropped[r])[0]:
            cid = int(cohort[p])
            assert out.n_evals[r][p] == 0
            assert np.isnan(out.losses[r][p])
            assert not out.selected[r][p]
            assert out.budgets[r][cid] == prev_b[cid]
            assert out.cum_evals[r][cid] == prev_c[cid]

    # the population outruns the cohorts: some client is never trained
    # and its final carries sit at their init values
    never = sorted(set(range(C)) - sampled)
    assert never, "population too small to leave an untouched client"
    for cid in never:
        assert out.budgets_final[cid] == 3
        assert out.cum_evals_final[cid] == 0
        assert np.isinf(out.last_losses_final[cid])

    again = driver.run(theta0)
    for field in ("cohort", "dropped", "selected", "losses", "n_evals",
                  "budgets", "cum_evals", "theta", "theta_g",
                  "server_loss", "budgets_final", "last_losses_final",
                  "cum_evals_final"):
        np.testing.assert_array_equal(getattr(out, field),
                                      getattr(again, field),
                                      err_msg=field)


# ---------------------------------------------------------------------------
# property tests: the traceable twins vs their host reference modules
# ---------------------------------------------------------------------------
# binary-fraction grid: |a - b| is exact in BOTH f32 and f64, so the two
# precisions order distances identically and ties are genuine ties
_GRID = [-2.0, -0.75, -0.5, -0.25, 0.0, 0.25, 0.5, 0.75, 1.0, 1.5, 2.0,
         float("inf"), float("-inf"), float("nan")]
_FINITE = [v for v in _GRID if np.isfinite(v)]


@given(st.lists(st.sampled_from(_GRID), min_size=1, max_size=12),
       st.sampled_from(_FINITE),
       st.sampled_from([0.05, 0.25, 0.5, 0.75, 1.0]))
@settings(max_examples=60, deadline=None)
def test_prop_select_topk_mask_matches_select_aligned(losses, s, frac):
    k = max(1, int(round(frac * len(losses))))
    d = selection.distances(losses, s)
    mask = np.asarray(select_topk_mask(d, k))
    assert sorted(np.nonzero(mask)[0].tolist()) == \
        selection.select_aligned(losses, s, frac)
    assert int(mask.sum()) == min(k, len(losses))


def test_select_topk_mask_ties_and_nonfinite():
    # ties resolve to the lower index (stable argsort), non-finite
    # sorts last, k=1 and k=n edges behave
    d = np.asarray([1.0, 0.5, 0.5, np.nan, np.inf, 0.5])
    np.testing.assert_array_equal(
        np.asarray(select_topk_mask(d, 2)),
        [False, True, True, False, False, False])
    np.testing.assert_array_equal(
        np.asarray(select_topk_mask(d, 1)),
        [False, True, False, False, False, False])
    np.testing.assert_array_equal(np.asarray(select_topk_mask(d, 6)),
                                  [True] * 6)
    # all-non-finite: still returns exactly k (arbitrary but stable)
    assert int(np.asarray(select_topk_mask(
        np.asarray([np.nan, np.inf]), 1)).sum()) == 1


@given(st.integers(1, 120),
       st.floats(0.01, 8.0),
       st.sampled_from([0.25, 0.5, 1.0, 2.0, 4.0, 0.0, -1.0,
                        float("inf"), float("nan")]),
       st.sampled_from(regulation_mod.VARIANTS),
       st.integers(3, 40))
@settings(max_examples=80, deadline=None)
def test_prop_regulate_batched_matches_host(m, q, llm, variant, cap):
    q = float(np.float32(q))          # feed both paths the same f32 value
    host = regulation_mod.regulate(m, q, llm, variant=variant, cap=cap)
    got = int(regulate_batched(m, q, llm, variant=variant, cap=cap))
    # knife-edge guard: if a ±2e-6 relative nudge of q moves the host
    # result, the f32 twin may land on either side — bracket it.  The
    # formulas are monotone in q so the bracket is tight.
    lo = regulation_mod.regulate(m, q * (1 - 2e-6), llm, variant=variant,
                                 cap=cap)
    hi = regulation_mod.regulate(m, q * (1 + 2e-6), llm, variant=variant,
                                 cap=cap)
    if lo == hi:
        assert got == host, (m, q, llm, variant, cap)
    else:
        assert min(lo, hi) <= got <= max(lo, hi)
    # clamp law: whenever the LLM reference is usable the result is in
    # [min_iter, cap]; a bad reference leaves maxiter untouched
    if llm > 0 and np.isfinite(llm):
        assert 1 <= got <= cap
    else:
        assert got == m


@given(st.integers(1, 120), st.sampled_from([0.5, 1.0, 2.0]),
       st.floats(0.02, 4.0), st.floats(0.02, 4.0),
       st.sampled_from(regulation_mod.VARIANTS))
@settings(max_examples=40, deadline=None)
def test_prop_regulate_batched_monotone(m, llm, q1, q2, variant):
    """More behind (larger QNN loss) never means fewer iterations."""
    ql, qh = sorted([q1, q2])
    assert int(regulate_batched(m, qh, llm, variant=variant)) >= \
        int(regulate_batched(m, ql, llm, variant=variant))


def test_regulate_batched_guard_ladder():
    # bad LLM reference: unchanged, NOT clamped (host quirk preserved)
    assert int(regulate_batched(200, 5.0, 0.0, cap=10)) == 200
    assert int(regulate_batched(200, 5.0, float("nan"), cap=10)) == 200
    # diverged client / not behind: hold the budget, clamped
    assert int(regulate_batched(200, float("nan"), 1.0, cap=10)) == 10
    assert int(regulate_batched(5, 0.5, 1.0, cap=10)) == 5
    # behind: boost and clamp; elementwise over stacks
    np.testing.assert_array_equal(
        np.asarray(regulate_batched([4, 4, 4], [8.0, 2.0, 1.0],
                                    [1.0, 1.0, 2.0], cap=10)),
        [10, 8, 4])
    with pytest.raises(ValueError, match="variant"):
        regulate_batched(4, 2.0, 1.0, variant="nope")


@given(st.lists(st.sampled_from([0.0, 0.25, 0.5, 0.75, 1.0]),
                min_size=1, max_size=8),
       st.sampled_from([1e-3, 0.3, 0.9]),
       st.integers(1, 2), st.integers(1, 8))
@settings(max_examples=60, deadline=None)
def test_prop_termination_step_matches_criterion(seq, eps, patience,
                                                 t_max):
    crit = TerminationCriterion(epsilon=eps, t_max=t_max,
                                patience=patience)
    prev, small = np.float32(np.nan), np.int32(0)
    for t, loss in enumerate(seq, 1):
        want = crit.update(loss, t)
        stop, small = termination_step(prev, small, loss, t, epsilon=eps,
                                       t_max=t_max, patience=patience)
        prev = np.float32(loss)
        assert bool(stop) == want, (seq, eps, patience, t_max, t)
        if want:
            break


def test_termination_step_tmax_before_patience():
    """At t == t_max the host returns early WITHOUT updating the
    patience counter — the fused twin must leave `small` stale too."""
    stop, small = termination_step(np.float32(1.0), np.int32(0),
                                   1.0, 2, epsilon=0.9, t_max=2)
    assert bool(stop) and int(small) == 0  # rel=0 < ε, yet not counted
    # one round earlier the same losses DO count toward patience
    stop, small = termination_step(np.float32(1.0), np.int32(0),
                                   1.0, 2, epsilon=0.9, t_max=5)
    assert bool(stop) and int(small) == 1
    # zero-loss plateau converges; a fresh drop to 0 is progress
    stop, _ = termination_step(np.float32(0.0), np.int32(0), 0.0, 3,
                               epsilon=1e-3, t_max=9)
    assert bool(stop)
    stop, _ = termination_step(np.float32(0.5), np.int32(0), 0.0, 3,
                               epsilon=1e-3, t_max=9)
    assert not bool(stop)


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------
def test_fused_requires_batched_engine():
    task = _task(3, 90, 45, 30, 5)
    with pytest.raises(ValueError, match="batched"):
        run_experiment(task, rounds="fused", engine="sequential")
    with pytest.raises(ValueError, match="rounds"):
        run_experiment(task, rounds="warp")


def test_population_knobs_require_fused_rounds():
    task = _task(3, 90, 45, 30, 5)
    with pytest.raises(ValueError, match="fused"):
        run_experiment(task, engine="batched", c_round=2)
    with pytest.raises(ValueError, match="fused"):
        run_experiment(task, engine="batched", dropout=0.5)


def test_driver_validation():
    task = _task(3, 90, 45, 30, 5)
    spec = qnn.QNNSpec("vqc", n_qubits=4, n_classes=task.n_classes)
    be = backend_mod.get("exact")
    with pytest.raises(ValueError, match="c_round"):
        FusedRoundDriver(task, spec, be, c_round=0)
    with pytest.raises(ValueError, match="c_round"):
        FusedRoundDriver(task, spec, be, c_round=4)
    with pytest.raises(ValueError, match="dropout"):
        FusedRoundDriver(task, spec, be, dropout=1.0)
    with pytest.raises(ValueError, match="use_llm"):
        FusedRoundDriver(task, spec, be, use_llm=True)
    # c_round == C collapses to full participation
    assert FusedRoundDriver(task, spec, be, c_round=3).c_round is None


# ---------------------------------------------------------------------------
# the 'clients' mesh: population stacks sharded 8 ways
# ---------------------------------------------------------------------------
def _assert_sharded_pop_parity(one, shard, C):
    """Keys and integers are position-pure → exact; float paths absorb
    the mesh's per-shard reduction reordering (f32 ulps)."""
    for field in ("cohort", "dropped", "selected", "n_evals"):
        np.testing.assert_array_equal(getattr(one, field),
                                      getattr(shard, field),
                                      err_msg=field)
    np.testing.assert_array_equal(one.cum_evals[:, :C],
                                  shard.cum_evals[:, :C])
    np.testing.assert_array_equal(one.budgets[:, :C],
                                  shard.budgets[:, :C])
    np.testing.assert_array_equal(np.isnan(one.losses),
                                  np.isnan(shard.losses))
    np.testing.assert_allclose(one.losses, shard.losses, atol=1e-5)
    np.testing.assert_allclose(one.server_loss, shard.server_loss,
                               atol=1e-5)
    np.testing.assert_allclose(one.theta_g, shard.theta_g, atol=1e-5)


@multi_device
def test_population_sharded_parity():
    """C_pop=12 padded to 16 over 8 devices, cohorts of 8: the sharded
    fused scan equals the single-device one."""
    kw = dict(backend="fake", dropout=0.25, c_round=8, n_rounds=3)
    one, theta0 = _pop_driver(**kw)
    shard, _ = _pop_driver(n_devices=8, **kw)
    _assert_sharded_pop_parity(one.run(theta0), shard.run(theta0), 12)


_CHILD = r"""
import json
import numpy as np
import jax
from repro.data.tasks import build_task
from repro.core.fused_rounds import FusedRoundDriver
from repro.quantum import backends as backend_mod
from repro.quantum import qnn

task = build_task("genomic", n_clients=12, train_size=96, test_size=32,
                  val_size=32, seed=7)
spec = qnn.QNNSpec("vqc", n_qubits=4, n_classes=task.n_classes)
be = backend_mod.get("fake")
theta0 = np.asarray(spec.init_params(jax.random.PRNGKey(11)), np.float64)
kw = dict(optimizer="spsa", seed=4, maxiter0=3, n_rounds=3,
          early_stop=False, c_round=8, dropout=0.25)
one = FusedRoundDriver(task, spec, be, **kw).run(theta0)
shard = FusedRoundDriver(task, spec, be, n_devices=8, **kw).run(theta0)
C = task.n_clients
eq = lambda f: bool(np.array_equal(getattr(one, f), getattr(shard, f)))
print("RESULT:" + json.dumps({
    "cohort_equal": eq("cohort"), "dropped_equal": eq("dropped"),
    "sel_equal": eq("selected"), "nevals_equal": eq("n_evals"),
    "cum_equal": bool(np.array_equal(one.cum_evals[:, :C],
                                     shard.cum_evals[:, :C])),
    "nan_equal": bool(np.array_equal(np.isnan(one.losses),
                                     np.isnan(shard.losses))),
    "dloss": float(np.nanmax(np.abs(one.losses - shard.losses))),
    "dtheta": float(np.abs(one.theta_g - shard.theta_g).max()),
}))
"""


@pytest.mark.skipif(
    len(jax.devices()) >= 8,
    reason="a real mesh is visible — the in-process parity test above "
           "covers this; don't pay the heavy child interpreter twice")
def test_population_sharded_parity_forced_host_devices():
    """Force 8 host devices in a fresh interpreter (XLA_FLAGS must be
    set before jax initializes) and require the sharded population scan
    to match the single-device one, keys and padding included."""
    env = dict(os.environ)
    flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                   env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = (flags
                        + " --xla_force_host_platform_device_count=8"
                        ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULT:")][-1]
    got = json.loads(line[len("RESULT:"):])
    for k in ("cohort_equal", "dropped_equal", "sel_equal",
              "nevals_equal", "cum_equal", "nan_equal"):
        assert got[k], got
    assert got["dloss"] <= 1e-5, got
    assert got["dtheta"] <= 1e-5, got
