"""Finite-shot sampling: the keyed noise contract end to end.

Table I's noisy backends (fake/aersim/real, shots=100) must actually
*sample* — deterministic-by-seed, raising when a sampling context has no
key, degenerate-input-safe, and live in accuracy/loss reporting — rather
than silently running the deterministic channel (the regression this
suite pins down: no call site passed a key, so shot noise never fired).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.orchestrator import run_experiment
from repro.data.tasks import build_task
from repro.quantum import backends

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def small_task():
    return build_task("genomic", n_clients=3, train_size=90, test_size=45,
                      val_size=30, seed=5)


# --- transform_probs contract -------------------------------------------------
def test_transform_probs_requires_key_when_sampling():
    """shots>0 without a key must raise — not silently skip sampling."""
    p = jnp.array([[0.9, 0.1]])
    for name in ("fake", "aersim", "real"):
        with pytest.raises(ValueError, match="shots"):
            backends.get(name).transform_probs(p)
        # channel-only evaluation is an explicit opt-in
        out = backends.get(name).apply_channel(p)
        assert np.isfinite(np.asarray(out)).all()
    # exact (shots=0) stays key-free
    np.testing.assert_allclose(
        np.asarray(backends.get("exact").transform_probs(p)), p)


def test_transform_probs_samples_with_key():
    """With a key, the output is an empirical shot frequency: quantized
    to multiples of 1/shots and != the channel output in general."""
    b = backends.get("fake")
    p = jnp.tile(jnp.array([[0.7, 0.3]]), (8, 1))
    out = np.asarray(b.transform_probs(p, key=KEY))
    chan = np.asarray(b.apply_channel(p))
    quant = out * b.shots
    np.testing.assert_allclose(quant, np.round(quant), atol=1e-4)
    assert not np.allclose(out, chan)
    # same key → same draws; different key → (generically) different
    out2 = np.asarray(b.transform_probs(p, key=KEY))
    np.testing.assert_array_equal(out, out2)
    out3 = np.asarray(b.transform_probs(p, key=jax.random.PRNGKey(1)))
    assert not np.array_equal(out, out3)


def test_transform_probs_traceable_under_jit_and_vmap():
    """The sampling stage is usable inside compiled programs — the fused
    round engine's requirement."""
    b = backends.get("fake")
    p = jnp.tile(jnp.array([[0.6, 0.4]]), (4, 1))

    jit_out = jax.jit(b.transform_probs)(p, KEY)
    np.testing.assert_array_equal(np.asarray(jit_out),
                                  np.asarray(b.transform_probs(p, KEY)))

    stack = jnp.stack([p, p])
    keys = jnp.stack([KEY, jax.random.PRNGKey(7)])
    vout = jax.vmap(b.transform_probs)(stack, keys)
    assert vout.shape == stack.shape


# --- sample_counts hardening --------------------------------------------------
def test_sample_counts_zero_mass_rows_fall_back_to_uniform():
    """Regression: an all-zero row used to dump every shot into class
    C-1 through the clamped searchsorted."""
    shots = 3000
    p = jnp.array([[0.0, 0.0, 0.0], [0.2, 0.3, 0.5]])
    counts = np.asarray(backends.sample_counts(KEY, p, shots))
    np.testing.assert_allclose(counts.sum(axis=1), shots)
    np.testing.assert_allclose(counts[0] / shots, [1 / 3] * 3, atol=0.04)
    np.testing.assert_allclose(counts[1] / shots, [0.2, 0.3, 0.5],
                               atol=0.04)
    # negative-clip degenerate row behaves the same
    neg = jnp.array([[-1.0, -2.0, -0.5]])
    counts = np.asarray(backends.sample_counts(KEY, neg, shots))
    np.testing.assert_allclose(counts[0] / shots, [1 / 3] * 3, atol=0.04)


def test_sample_counts_nan_rows_propagate():
    """A diverged (NaN) probability row must come back NaN — not be
    laundered into a plausible finite loss by the zero-mass→uniform
    fallback — so ``selection.py``'s +inf hardening still sees it on
    noisy backends."""
    shots = 200
    p = jnp.array([[jnp.nan, 0.5, 0.5], [0.2, 0.3, 0.5]])
    counts = np.asarray(backends.sample_counts(KEY, p, shots))
    assert np.isnan(counts[0]).all()
    np.testing.assert_allclose(counts[1].sum(), shots)
    # draw-stability: the finite row's counts are bitwise what they are
    # when the NaN row is replaced by any finite distribution — NaN
    # handling must not shift other rows' draws (pinned parity seeds)
    p_ref = jnp.array([[1 / 3, 1 / 3, 1 / 3], [0.2, 0.3, 0.5]])
    ref = np.asarray(backends.sample_counts(KEY, p_ref, shots))
    np.testing.assert_array_equal(counts[1], ref[1])
    # ...and a genuinely zero-mass row still falls back to uniform
    np.testing.assert_allclose(
        np.asarray(backends.sample_counts(
            KEY, jnp.array([[0.0, 0.0, 0.0]]), 3000))[0] / 3000,
        [1 / 3] * 3, atol=0.04)


def test_nan_probs_surface_as_inf_distance_in_selection():
    """End to end: a diverged client's sampled loss is NaN, which the
    alignment selector sorts last as +inf instead of averaging in."""
    from repro.core import selection
    b = backends.get("fake")
    p = jnp.array([[jnp.nan, jnp.nan], [0.6, 0.4]])
    noisy = np.asarray(b.transform_probs(p, key=KEY))
    assert np.isnan(noisy[0]).all()
    losses = [float(-np.log(noisy[i].max() + 1e-9)) for i in range(2)]
    d = selection.distances(losses, 0.5)
    assert d[0] == np.inf and np.isfinite(d[1])
    assert selection.select_aligned(losses, 0.5, 0.5) == [1]


def test_sample_counts_dtype_follows_probs():
    p16 = jnp.array([[0.5, 0.5]], jnp.bfloat16)
    assert backends.sample_counts(KEY, p16, 10).dtype == jnp.bfloat16
    p32 = jnp.array([[0.5, 0.5]], jnp.float32)
    assert backends.sample_counts(KEY, p32, 10).dtype == jnp.float32


def test_sample_counts_low_precision_does_not_saturate():
    """Counts accumulate in f32 before the dtype cast: a bfloat16 input
    with shots > 256 must not plateau at 256 (bf16's integer ceiling)."""
    p = jnp.array([[1.0, 0.0]], jnp.bfloat16)
    counts = backends.sample_counts(KEY, p, 1000)
    assert float(counts[0, 0]) == pytest.approx(1000, rel=0.01)


# --- key derivation -----------------------------------------------------------
def test_eval_key_distinct_across_round_client_slot():
    base = jax.random.PRNGKey(3)
    seen = set()
    for r in (1, 2):
        for c in (0, 1, backends.SERVER_CLIENT):
            for s in (0, 1, backends.REPORT_EVAL_SLOT,
                      backends.FINAL_EVAL_SLOT):
                seen.add(tuple(np.asarray(
                    backends.eval_key(base, r, c, s)).tolist()))
    assert len(seen) == 2 * 3 * 4
    # deterministic
    np.testing.assert_array_equal(
        np.asarray(backends.eval_key(base, 1, 0, 5)),
        np.asarray(backends.eval_key(base, 1, 0, 5)))


# --- end-to-end: shot noise is live and deterministic ------------------------
def test_noisy_run_deterministic_by_seed(small_task):
    kw = dict(method="qfl", optimizer="spsa", n_rounds=2, maxiter0=3,
              early_stop=False, backend="fake", seed=4)
    a = run_experiment(small_task, **kw)
    b = run_experiment(small_task, **kw)
    assert a.series("server_loss") == b.series("server_loss")
    assert a.series("server_val_acc") == b.series("server_val_acc")
    np.testing.assert_array_equal(a.theta_g, b.theta_g)


def test_shot_sampling_changes_trajectory(small_task):
    """shots_override=0 (channel-only ablation) must differ from the
    default finite-shot run — i.e. sampling actually fires."""
    kw = dict(method="qfl", optimizer="spsa", n_rounds=2, maxiter0=3,
              early_stop=False, backend="fake", seed=4)
    shot = run_experiment(small_task, **kw)
    noshot = run_experiment(small_task, shots_override=0, **kw)
    assert shot.series("server_loss") != noshot.series("server_loss")


def test_shots_override_changes_quantization(small_task):
    """A 10-shot run quantizes losses more coarsely than a 1000-shot
    run; both stay finite and deterministic."""
    kw = dict(method="qfl", optimizer="spsa", n_rounds=1, maxiter0=2,
              early_stop=False, backend="fake", seed=4)
    coarse = run_experiment(small_task, shots_override=10, **kw)
    fine = run_experiment(small_task, shots_override=1000, **kw)
    assert coarse.series("server_loss") != fine.series("server_loss")
    for res in (coarse, fine):
        assert all(np.isfinite(r.server_loss) for r in res.rounds)


def test_shots_override_rejects_negative(small_task):
    with pytest.raises(ValueError):
        run_experiment(small_task, shots_override=-1, n_rounds=1)


def test_accuracy_measured_through_backend(small_task):
    """Satellite: server accuracy goes through the measurement pipeline
    (channel + shots), so noisy-backend accuracy differs from the same
    run evaluated exactly — the Table-I ordering is measured."""
    kw = dict(method="qfl", optimizer="spsa", n_rounds=2, maxiter0=3,
              early_stop=False, seed=4)
    exact = run_experiment(small_task, backend="exact", **kw)
    fake = run_experiment(small_task, backend="fake", **kw)
    accs_e = exact.series("server_val_acc") + exact.series("server_test_acc")
    accs_f = fake.series("server_val_acc") + fake.series("server_test_acc")
    assert accs_e != accs_f


def test_fully_depolarized_accuracy_is_chance(small_task):
    """A depolarizing=1.0 channel erases the model: every row becomes
    uniform, argmax degenerates to class 0, and accuracy equals the
    class-0 rate of the split — which only happens if _acc applies the
    channel (the old code ignored the backend entirely)."""
    from repro.core.orchestrator import Orchestrator, RunConfig
    orch = Orchestrator(small_task, RunConfig(method="qfl", n_rounds=1))
    orch.backend = backends.Backend("flat", depolarizing=1.0)
    theta = np.zeros(orch.spec.n_params)
    acc = orch._acc(theta, small_task.val_qX, small_task.val_qy)
    class0 = np.mean(np.asarray(small_task.val_qy) == 0)
    assert acc == pytest.approx(float(class0))
