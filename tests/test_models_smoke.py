"""Per-architecture smoke tests (assignment requirement): reduced variant of
each family — one forward, one train step, one decode step on CPU; output
shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import assigned_names, get
from repro.models import model as M
from repro.optim import adamw

ARCHS = assigned_names()
KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=64):
    b = {"tokens": jnp.ones((B, S), jnp.int32),
         "labels": jnp.concatenate(
             [jnp.ones((B, S - 1), jnp.int32),
              jnp.full((B, 1), -1, jnp.int32)], axis=1)}
    if cfg.frontend or cfg.encoder_decoder:
        b["frontend"] = jnp.ones((B, cfg.n_frontend_tokens, cfg.d_model),
                                 jnp.bfloat16) * 0.01
    return b


@pytest.fixture(scope="module")
def built():
    """Init params+adapters once per arch (reduced config)."""
    out = {}
    for name in ARCHS:
        cfg = get(name + "-smoke")
        p = M.init_params(cfg, KEY)
        a = M.init_adapters(cfg, KEY, p)
        out[name] = (cfg, p, a)
    return out


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_finite(built, name):
    cfg, p, a = built[name]
    B, S = 2, 64
    h, bal, _ = M.forward(cfg, p, a, _batch(cfg, B, S))
    assert h.shape == (B, S, cfg.d_model)
    assert bool(jnp.isfinite(h.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(bal))


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_reduces_loss_finite(built, name):
    cfg, p, a = built[name]
    step = jax.jit(M.make_train_step(cfg, n_microbatches=2, lr=5e-3))
    st = adamw.init(a)
    batch = _batch(cfg, 4, 64)
    a1, st1, m1 = step(p, a, st, batch)
    a2, st2, m2 = step(p, a1, st1, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"]) + 0.5  # not diverging
    assert float(m1["grad_norm"]) > 0                   # adapters learn


@pytest.mark.parametrize("name", ARCHS)
def test_decode_step(built, name):
    cfg, p, a = built[name]
    B, S = 2, 64
    cache = M.init_cache(cfg, B, S)
    serve = jax.jit(M.make_serve_step(cfg))
    logits, cache = serve(p, a, cache, jnp.ones((B, 1), jnp.int32),
                          jnp.asarray(3))
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    # second token advances without shape drift
    logits2, _ = serve(p, a, cache, jnp.ones((B, 1), jnp.int32),
                       jnp.asarray(4))
    assert logits2.shape == (B, cfg.vocab_size)


@pytest.mark.parametrize("name", ARCHS)
def test_reduced_config_limits(name):
    """Assignment: smoke variants must be ≤2 layers-worth of pattern,
    d_model ≤ 512, ≤4 experts."""
    cfg = get(name + "-smoke")
    assert cfg.d_model <= 512
    assert cfg.n_groups <= 2
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("name", ARCHS)
def test_full_config_matches_assignment(name):
    """The full configs carry the exact assigned numbers."""
    table = {
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    }
    L, d, H, KH, ff, V = table[name]
    cfg = get(name)
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.n_heads == H and cfg.n_kv_heads == KH
    assert cfg.d_ff == ff and cfg.vocab_size == V


def test_moe_expert_counts():
    assert get("llama4-maverick-400b-a17b").moe.n_experts == 128
    assert get("llama4-maverick-400b-a17b").moe.top_k == 1
    assert get("kimi-k2-1t-a32b").moe.n_experts == 384
    assert get("kimi-k2-1t-a32b").moe.top_k == 8
    assert get("jamba-1.5-large-398b").moe.n_experts == 16
    assert get("jamba-1.5-large-398b").moe.top_k == 2
