"""The 'clients' mesh axis: spec builders, explicit ragged handling, and
multi-device parity of the sharded batched round engine.

The engine's sharding contract (see ``core/batched_engine.py``):
per-client programs are identical, key folding depends on client
*position* only, and padding clients are inert — so the sharded round
is draw-for-draw the single-device round, **bitwise** at pinned seeds
on quantizing paths (NM's branch ladder, finite-shot sampling) and
within f32 arithmetic-order noise (~2e-7, XLA's per-shard
re-vectorization of reductions) for noiseless SPSA, whose update
consumes raw f differences.  The in-process parity tests need >= 8
devices (CI runs them under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``); the subprocess
test forces 8 host devices in a child interpreter so single-device
tier-1 runs still cover the sharded path.
"""
import json
import os
import re
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


# --- unit: spec builders and explicit ragged handling ------------------------
def test_client_stack_spec_ranks():
    assert shd.client_stack_spec(3) == P("clients", None, None)
    assert shd.client_stack_spec(2) == P("clients", None)
    assert shd.client_stack_spec(1) == P("clients")
    assert shd.client_stack_spec(0) == P()


def test_client_specs_shards_stacks_replicates_rest():
    C = 6
    arrays = {
        "qX": np.zeros((C, 12, 4)), "qy": np.zeros((C, 12)),
        "mask": np.zeros((C, 12)), "iters": np.zeros((C,)),
        "ckeys": np.zeros((C, 2), np.uint32),
        "theta_g": np.zeros((16,)),          # P != C → replicated
    }
    specs = shd.client_specs(arrays, C)
    assert specs["qX"] == P("clients", None, None)
    assert specs["qy"] == P("clients", None)
    assert specs["iters"] == P("clients")
    assert specs["ckeys"] == P("clients", None)
    assert specs["theta_g"] == P()


def test_pad_client_count():
    assert shd.pad_client_count(5, 8) == 8
    assert shd.pad_client_count(8, 8) == 8
    assert shd.pad_client_count(9, 8) == 16
    assert shd.pad_client_count(16, 1) == 16
    with pytest.raises(ValueError):
        shd.pad_client_count(4, 0)


def test_ragged_clients_error_is_explicit():
    """Ragged C over the mesh is a named error telling you to pad — not
    an XLA crash or a silent reshard."""
    with pytest.raises(ValueError, match="pad"):
        shd.check_client_divisibility(5, 8)
    shd.check_client_divisibility(16, 8)     # divisible: no raise
    shd.check_client_divisibility(5, 1)      # single shard: any C


def test_client_mesh_rejects_oversubscription():
    with pytest.raises(ValueError, match="force_host_platform"):
        shd.client_mesh(10 ** 6)
    with pytest.raises(ValueError):
        shd.client_mesh(0)


def test_put_client_stacks_roundtrip_single_shard():
    mesh = shd.client_mesh(1)
    x = np.arange(12, dtype=np.float32).reshape(3, 4)
    th = np.arange(3, dtype=np.float32) + 7    # leading dim == C: footgun
    (xs,) = shd.put_client_stacks(mesh, (x,), 3)
    np.testing.assert_array_equal(np.asarray(xs), x)
    thr = shd.put_replicated(mesh, th)
    np.testing.assert_array_equal(np.asarray(thr), th)
    assert thr.sharding.spec == P()


# --- in-process parity on a real >= 8 device mesh (CI multi-device step) -----
def _pair_by_devices(task, n_devices, **kw):
    from repro.core.orchestrator import run_experiment
    one = run_experiment(task, engine="batched", **kw)
    shard = run_experiment(task, engine="batched", n_devices=n_devices,
                           **kw)
    return one, shard


def _assert_bitwise(one, shard):
    assert shard.series("server_loss") == one.series("server_loss")
    assert shard.series("cum_evals") == one.series("cum_evals")
    assert shard.series("selected") == one.series("selected")
    np.testing.assert_array_equal(shard.theta_g, one.theta_g)


@multi_device
def test_sharded_parity_noiseless_nm():
    """8-way client mesh == single device, bitwise (paper's default NM)."""
    from repro.data.tasks import build_task
    task = build_task("genomic", n_clients=8, train_size=64, test_size=24,
                      val_size=24, seed=5)
    one, shard = _pair_by_devices(
        task, 8, method="qfl", optimizer="nelder-mead", n_rounds=2,
        maxiter0=3, early_stop=False)
    _assert_bitwise(one, shard)


@multi_device
def test_sharded_parity_shots():
    """Finite-shot draws survive sharding: key folding is position-based
    so every client samples identically wherever its shard lands."""
    from repro.data.tasks import build_task
    task = build_task("genomic", n_clients=8, train_size=64, test_size=24,
                      val_size=24, seed=5)
    one, shard = _pair_by_devices(
        task, 8, method="qfl", optimizer="spsa", n_rounds=2,
        maxiter0=3, early_stop=False, backend="fake", seed=4)
    _assert_bitwise(one, shard)


@multi_device
def test_sharded_noiseless_spsa_tolerance():
    """Noiseless SPSA is the one cell without quantization to absorb
    XLA's per-shard reduction re-vectorization: draw/eval accounting is
    still exact, trajectories agree to f32 arithmetic-order noise."""
    from repro.data.tasks import build_task
    task = build_task("genomic", n_clients=3, train_size=60, test_size=24,
                      val_size=24, seed=1)
    one, shard = _pair_by_devices(
        task, 8, method="qfl", optimizer="spsa", n_rounds=2,
        maxiter0=4, early_stop=False)
    assert shard.series("cum_evals") == one.series("cum_evals")
    assert shard.series("selected") == one.series("selected")
    gap = max(abs(a - b) for a, b in zip(one.series("server_loss"),
                                         shard.series("server_loss")))
    assert gap <= 1e-6
    np.testing.assert_allclose(shard.theta_g, one.theta_g, atol=1e-6)


@multi_device
def test_sharded_parity_ragged_padding():
    """C=5 on an 8-way mesh: 3 inert padding clients, outputs sliced —
    still bitwise vs the unpadded single-device run."""
    from repro.data.tasks import build_task
    task = build_task("genomic", n_clients=5, train_size=50, test_size=20,
                      val_size=20, seed=7)
    one, shard = _pair_by_devices(
        task, 8, method="qfl", optimizer="nelder-mead", n_rounds=2,
        maxiter0=3, early_stop=False, backend="fake", seed=2)
    _assert_bitwise(one, shard)


# --- subprocess: sharded-path coverage from a single-device tier-1 run -------
_CHILD = r"""
import json
import numpy as np
from repro.data.tasks import build_task
from repro.core.orchestrator import run_experiment

task = build_task("genomic", n_clients=5, train_size=40, test_size=15,
                  val_size=15, seed=7)
kw = dict(method="qfl", optimizer="nelder-mead", n_rounds=2, maxiter0=2,
          early_stop=False, backend="fake", seed=2, engine="batched")
one = run_experiment(task, **kw)
shard = run_experiment(task, n_devices=8, **kw)
print("RESULT:" + json.dumps({
    "loss_equal": shard.series("server_loss") == one.series("server_loss"),
    "evals_equal": shard.series("cum_evals") == one.series("cum_evals"),
    "dtheta": float(np.abs(shard.theta_g - one.theta_g).max()),
}))
"""


@pytest.mark.skipif(
    len(jax.devices()) >= 8,
    reason="a real mesh is visible — the in-process parity tests above "
           "cover this; don't pay the heavy child interpreter twice")
def test_sharded_parity_forced_host_devices():
    """Force 8 host devices in a fresh interpreter (XLA_FLAGS must be set
    before jax initializes, which the parent's jax no longer allows) and
    require bitwise parity, keys and padding included."""
    env = dict(os.environ)
    # replace (not just append) any inherited force-count: a parent
    # forcing 2..7 devices would otherwise leak through and the child's
    # n_devices=8 mesh would refuse to build
    flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                   env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = (flags
                        + " --xla_force_host_platform_device_count=8"
                        ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULT:")][-1]
    got = json.loads(line[len("RESULT:"):])
    assert got["loss_equal"], got
    assert got["evals_equal"], got
    assert got["dtheta"] == 0.0, got
