import os

# Smoke tests and benches must see ONE device (the dry-run forces 512 in
# its own process only).  Keep XLA quiet and single-threaded-ish on CI.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
