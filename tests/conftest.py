import os

# Smoke tests and benches must see ONE device (the dry-run forces 512 in
# its own process only).  Keep XLA quiet and single-threaded-ish on CI.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

# ---------------------------------------------------------------------------
# hypothesis fallback: the property tests only use a tiny strategy subset
# (integers / floats / lists).  When hypothesis is not installed, vendor a
# deterministic stand-in that runs each property test on `max_examples`
# seeded-random samples, so `pytest -x -q` stays green with no extra deps.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    import functools
    import random
    import sys
    import types

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    def _integers(lo, hi):
        return _Strategy(lambda rng: rng.randint(lo, hi))

    def _floats(lo, hi, **_kw):
        return _Strategy(lambda rng: rng.uniform(lo, hi))

    def _lists(elem, min_size=0, max_size=10):
        return _Strategy(lambda rng: [elem.example(rng) for _ in
                                      range(rng.randint(min_size, max_size))])

    def _sampled_from(seq):
        return _Strategy(lambda rng: rng.choice(list(seq)))

    def _settings(max_examples=20, **_kw):
        def deco(f):
            f._max_examples = max_examples
            return f
        return deco

    def _given(*strategies):
        def deco(f):
            @functools.wraps(f)
            def wrapper(*args, **kwargs):
                rng = random.Random(0)
                for _ in range(getattr(f, "_max_examples", 20)):
                    drawn = [s.example(rng) for s in strategies]
                    f(*args, *drawn, **kwargs)
            # hide the wrapped signature or pytest treats the strategy
            # parameters as fixtures to inject
            del wrapper.__wrapped__
            return wrapper
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers, _st.floats = _integers, _floats
    _st.lists, _st.sampled_from = _lists, _sampled_from
    _hyp = types.ModuleType("hypothesis")
    _hyp.given, _hyp.settings, _hyp.strategies = _given, _settings, _st
    _hyp.HealthCheck = types.SimpleNamespace(too_slow=None)
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
