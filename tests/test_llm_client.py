"""LLM client: fine-tuning learns, teacher probs are calibrated,
adapter FedAvg/distillation behave."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.llm_client import (LLMClient, distill_to_global,
                                   fedavg_adapters, task_llm_config)
from repro.data.tasks import build_task
from repro.models import model as M


@pytest.fixture(scope="module")
def task():
    return build_task("genomic", n_clients=2, train_size=80, test_size=20,
                      val_size=20, seed=3)


@pytest.fixture(scope="module")
def clients(task):
    cfg = task_llm_config("tiny-llm", task.vocab_size, task.llm_seq_len)
    key = jax.random.PRNGKey(0)
    base = M.init_params(cfg, key, dtype=jnp.float32)
    out = []
    for i in range(task.n_clients):
        cl = LLMClient(cfg, base, jax.random.PRNGKey(i + 1),
                       n_labels=task.n_classes)
        out.append(cl)
    return out


def test_fine_tune_reduces_loss(task, clients):
    cl = clients[0]
    batch = task.clients[0].llm_batch
    before = cl.eval_loss(batch)
    cl.fine_tune(batch, steps=25)
    after = cl.eval_loss(batch)
    assert after < before


def test_teacher_probs_shape_simplex(task, clients):
    batch = task.clients[0].llm_batch
    p = clients[0].teacher_probs(batch)
    assert p.shape == (task.clients[0].n, task.n_classes)
    np.testing.assert_allclose(np.asarray(p.sum(1)), 1.0, atol=1e-5)


def test_f1_in_unit_interval(task, clients):
    f1 = clients[0].f1(task.clients[0].llm_batch)
    assert 0.0 <= f1 <= 1.0


def test_fedavg_adapters_weighted_mean():
    a = {"x": jnp.ones((2, 2))}
    b = {"x": jnp.zeros((2, 2))}
    avg = fedavg_adapters([a, b], [3.0, 1.0])
    np.testing.assert_allclose(np.asarray(avg["x"]), 0.75)


def test_distill_to_global_blends(task, clients):
    before = [jax.tree.leaves(c.adapters)[0].copy() for c in clients]
    distill_to_global(clients, task.weights[: len(clients)], rho=0.5)
    after = [jax.tree.leaves(c.adapters)[0] for c in clients]
    # clients move toward each other
    d_before = float(jnp.abs(before[0] - before[1]).mean())
    d_after = float(jnp.abs(after[0] - after[1]).mean())
    assert d_after <= d_before + 1e-9
