"""Doc-freshness guards: the README and architecture page must exist and
must not drift from the repo's operational ground truth (ROADMAP's tier-1
command, the key-derivation contract, the engine matrix).  CI runs this
file as an explicit step so a missing/stale README fails loudly, not
just as one line in the tier-1 tally.
"""
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
README = ROOT / "README.md"
ARCH = ROOT / "docs" / "ARCHITECTURE.md"
ROADMAP = ROOT / "ROADMAP.md"


def _tier1_command() -> str:
    """The canonical tier-1 command, parsed from ROADMAP.md (the single
    source of truth): the first backtick span after 'Tier-1 verify:'."""
    text = ROADMAP.read_text()
    m = re.search(r"\*\*Tier-1 verify:\*\*\s*`([^`]+)`", text)
    assert m, "ROADMAP.md lost its '**Tier-1 verify:** `...`' line"
    return m.group(1)


def test_readme_exists():
    assert README.is_file(), "top-level README.md is missing"


def test_readme_tier1_command_matches_roadmap():
    """The verify command in the README must be ROADMAP's, verbatim —
    if one changes, change both (this is the drift guard)."""
    cmd = _tier1_command()
    assert cmd in README.read_text(), (
        f"README.md does not contain the tier-1 command from ROADMAP.md: "
        f"{cmd!r}")


def test_readme_covers_the_engine_matrix():
    text = README.read_text()
    for needle in ("sequential", "batched", "exact", "fake",
                   "benchmarks", 'pip install -e ".[test]"'):
        assert needle in text, f"README.md lost its {needle!r} section"


def test_architecture_page_documents_the_contracts():
    assert ARCH.is_file(), "docs/ARCHITECTURE.md is missing"
    text = ARCH.read_text()
    # the eval-key slot contract must be documented outside CHANGES.md
    assert "eval_key" in text
    assert re.search(r"slot", text, re.I)
    # the round pipeline map and the clients mesh axis
    for needle in ("tape", "clients", "shard", "aggregation"):
        assert needle in text, f"ARCHITECTURE.md lost its {needle!r} part"
