"""Batched Nelder–Mead vs the sequential simplex reference.

The contract is *decision parity*: on the same objective, the batched
engine must take the same reflect/expand/contract/shrink branch as
``gradfree.nm_run`` at every iteration, spend the same sequential-
equivalent eval counts, and land on the same simplex (f32 noise aside).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import gradfree
from repro.optim.batched_nm import (BRANCH_CONTRACT, BRANCH_EXPAND_XE,
                                    BRANCH_EXPAND_XR, BRANCH_INACTIVE,
                                    BRANCH_REFLECT, BRANCH_SHRINK,
                                    batched_nm, best_point, init_simplexes)


def _quad_batch(centers):
    c = jnp.asarray(np.stack(centers), jnp.float32)
    return lambda xs: jnp.sum((xs - c) ** 2, axis=-1)


def _quad_host(center):
    c32 = np.asarray(center, np.float32)
    return lambda x: float(np.sum((np.asarray(x, np.float32) - c32) ** 2))


def test_batched_nm_matches_sequential_per_client():
    dim, iters = 6, np.array([12, 5, 0])
    centers = [np.linspace(-1, 1, dim) * (c + 1) for c in range(3)]
    x0 = np.full((3, dim), 0.5, np.float32)

    simplex, fvals, n_evals, branches = batched_nm(
        _quad_batch(centers), x0, iters, 12)
    xb, fb = best_point(simplex, fvals)

    for c in range(3):
        trace = []
        st = gradfree.nm_init(_quad_host(centers[c]), x0[c])
        st = gradfree.nm_run(_quad_host(centers[c]), st, int(iters[c]),
                             trace=trace)
        taken = [int(b) for b in branches[c] if b != BRANCH_INACTIVE]
        assert taken == trace                      # decision-for-decision
        assert int(n_evals[c]) == st.n_evals       # eval-for-eval
        np.testing.assert_allclose(np.asarray(xb[c]), st.best_x, atol=1e-5)
        np.testing.assert_allclose(float(fb[c]), st.best_f, atol=1e-5)

    # zero-budget client: simplex bitwise-frozen at init
    np.testing.assert_array_equal(
        np.asarray(simplex[2]),
        np.asarray(init_simplexes(jnp.asarray(x0))[2]))
    assert all(int(b) == BRANCH_INACTIVE for b in branches[2])


def test_batched_nm_exercises_all_branches():
    """Rosenbrock's bent valley forces every simplex transformation."""
    rosen_h = lambda x: float(
        (1 - x[0]) ** 2 + 100.0 * (x[1] - x[0] ** 2) ** 2)
    f = lambda xs: ((1 - xs[:, 0]) ** 2
                    + 100.0 * (xs[:, 1] - xs[:, 0] ** 2) ** 2)
    x0 = np.array([[-1.2, 1.0]], np.float32)
    m = 60
    _, _, n_evals, branches = batched_nm(f, x0, np.array([m]), m)

    trace = []
    st = gradfree.nm_init(rosen_h, x0[0])
    st = gradfree.nm_run(rosen_h, st, m, trace=trace)
    assert [int(b) for b in branches[0]] == trace
    assert int(n_evals[0]) == st.n_evals
    seen = set(trace)
    assert {BRANCH_REFLECT, BRANCH_CONTRACT} <= seen
    assert seen & {BRANCH_EXPAND_XE, BRANCH_EXPAND_XR, BRANCH_SHRINK}


def test_batched_nm_eval_accounting_per_branch():
    """n_evals = (n+1) init + Σ taken-branch cost (2 / 2 / 1 / 2 / 2+n)."""
    dim = 3
    centers = [np.ones(dim) * 2.0]
    x0 = np.zeros((1, dim), np.float32)
    m = 15
    _, _, n_evals, branches = batched_nm(_quad_batch(centers), x0,
                                         np.array([m]), m)
    cost = {BRANCH_EXPAND_XE: 2, BRANCH_EXPAND_XR: 2, BRANCH_REFLECT: 1,
            BRANCH_CONTRACT: 2, BRANCH_SHRINK: 2 + dim}
    want = dim + 1 + sum(cost[int(b)] for b in branches[0])
    assert int(n_evals[0]) == want


def test_batched_nm_converges_quadratic():
    # mirrors test_gradfree.test_nm_converges_quadratic (dim 4, 150 iters)
    centers = [np.ones(4)]
    x0 = np.zeros((1, 4), np.float32)
    simplex, fvals, _, _ = batched_nm(_quad_batch(centers), x0,
                                      np.array([150]), 150)
    _, fb = best_point(simplex, fvals)
    assert float(fb[0]) < 1e-6


def test_batched_nm_budget_masks_are_prefixes():
    """A client with budget k replays the first k decisions of a client
    with a larger budget (same start, same objective)."""
    dim = 4
    centers = [np.linspace(0.5, 2.0, dim)] * 2
    x0 = np.full((2, dim), 0.25, np.float32)
    _, _, _, branches = batched_nm(_quad_batch(centers), x0,
                                   np.array([4, 10]), 10)
    short = [int(b) for b in branches[0] if b != BRANCH_INACTIVE]
    long = [int(b) for b in branches[1]]
    assert len(short) == 4 and short == long[:4]
