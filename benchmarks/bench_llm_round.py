"""Sequential vs batched LLM fine-tuning stage (Alg. 1 Step 1).

Times the whole stage — per-client LoRA fine-tuning, the FedAvg
distillation blend, and the eval_loss/f1/teacher-probs label-head evals —
for the sequential host loop (``llm_client.run_sequential_stage``, C
clients × llm_steps host dispatches) and the fused device program
(``batched_llm.BatchedLLMEngine``, one jitted scan over vmapped train
steps).  Both draw under the ``llm_key(seed, client, step)`` contract,
so the parity row (max |Δ eval loss| / |Δ teacher|) is a correctness
gate, not just a smell test.

``--sweep-clients 8,16,32`` scales the client count (batched cold+warm
per point, 1 device vs the mesh when ``--n-devices`` > 1); ``--n-devices
N`` forces N host devices before jax initializes and shards the client
axis of the engine across the 'clients' mesh.  ``--smoke`` shrinks the
workload for CI.

Heavy imports live inside ``main`` so the device-count flag can be set
after argparse but before the first jax touch.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.hostdev import clamp_to_visible, force_host_devices


def main(argv=()):
    # default () — not None — so the run.py aggregator's ``main()`` call
    # never re-parses the aggregator's own sys.argv
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI workload (fewer steps/examples)")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--steps", type=int, default=None,
                    help="fine-tune steps per client (llm_steps)")
    ap.add_argument("--train-size", type=int, default=0,
                    help="TOTAL training examples across clients "
                         "(0 = 25/client smoke, 40/client full)")
    ap.add_argument("--n-devices", type=int, default=0,
                    help="force N host devices and shard the batched "
                         "stage over an N-wide 'clients' mesh (0 = off)")
    ap.add_argument("--sweep-clients", default="",
                    help="comma list of client counts (e.g. 8,16,32): "
                         "batched stage wall-time, 1 device vs the mesh")
    args = ap.parse_args(list(argv))

    if args.n_devices > 1 and "jax" not in sys.modules:
        force_host_devices(args.n_devices)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import emit, get_task
    from repro.core.batched_llm import BatchedLLMEngine
    from repro.core.llm_client import run_sequential_stage, task_llm_config
    from repro.models import model as M

    n_dev = clamp_to_visible(args.n_devices, "llm_round")

    steps = args.steps or (8 if args.smoke else 30)
    per_client = args.train_size // args.clients if args.train_size \
        else (25 if args.smoke else 40)
    seed = 0

    def make(clients):
        task = get_task("genomic", n_clients=clients,
                        train_size=per_client * clients, seed=seed)
        cfg = task_llm_config("tiny-llm", task.vocab_size,
                              task.llm_seq_len)
        base = M.init_params(cfg, jax.random.PRNGKey(seed),
                             dtype=jnp.float32)
        return task, cfg, base

    def run_batched(task, cfg, base, devices=None):
        t0 = time.perf_counter()
        eng = BatchedLLMEngine(task, cfg, base, seed=seed, steps=steps,
                               n_devices=devices)
        out = eng.run()
        return time.perf_counter() - t0, out

    t0 = time.time()
    rows = []
    task, cfg, base = make(args.clients)

    t_seq0 = time.perf_counter()
    _, seq_losses, seq_f1, seq_teachers = run_sequential_stage(
        task, cfg, base, seed=seed, steps=steps)
    t_seq = time.perf_counter() - t_seq0
    rows.append({"name": "sequential_stage_s", "value": f"{t_seq:.3f}",
                 "derived": (f"clients={args.clients} steps={steps} "
                             f"per_client={per_client}")})

    devices = n_dev if n_dev > 1 else None
    t_cold, out = run_batched(task, cfg, base, devices=devices)
    t_warm, out = run_batched(task, cfg, base, devices=devices)
    dloss = max(abs(a - b) for a, b in zip(seq_losses, out.losses))
    df1 = max(abs(a - b) for a, b in zip(seq_f1, out.f1))
    dteach = max(float(np.abs(np.asarray(ts, np.float32)
                              - out.teacher[i, :len(ts)]).max())
                 for i, ts in enumerate(seq_teachers))
    rows.append({"name": "batched_stage_cold_s", "value": f"{t_cold:.3f}",
                 "derived": (f"n_devices={devices or 1} "
                             f"speedup_vs_seq={t_seq / t_cold:.2f}x")})
    rows.append({"name": "batched_stage_warm_s", "value": f"{t_warm:.3f}",
                 "derived": (f"n_devices={devices or 1} "
                             f"speedup_vs_seq={t_seq / t_warm:.2f}x")})
    rows.append({"name": "parity_gap", "value": f"{dloss:.2e}",
                 "derived": (f"max|dL_LLM|={dloss:.2e} max|df1|={df1:.2e} "
                             f"max|dteacher|={dteach:.2e} "
                             f"gate:|dL|<=5e-3,|df1|<=0.1 "
                             f"(identical draws; fp32 arithmetic-order "
                             f"drift compounds over steps)")})
    if dloss > 5e-3 or df1 > 0.1:
        # the correctness gate: broken draw parity shows up as O(0.1)
        # gaps, far above fp32 drift — fail the CI step, don't just log
        emit("llm_round", rows, t0=t0)
        raise SystemExit(
            f"llm_round parity gate failed: dloss={dloss:.2e} "
            f"df1={df1:.2e}")

    if args.sweep_clients:
        sweep = [int(c) for c in args.sweep_clients.split(",") if c]
        mesh_w = n_dev if n_dev > 1 else len(jax.devices())
        for C in sweep:
            task, cfg, base = make(C)
            for devs in (None, mesh_w) if mesh_w > 1 else (None,):
                run_batched(task, cfg, base, devices=devs)     # compile
                wall, _ = run_batched(task, cfg, base, devices=devs)
                d = devs or 1
                rows.append({
                    "name": f"sweep_c{C}_d{d}_stage_s",
                    "value": f"{wall:.3f}",
                    "derived": (f"clients={C} n_devices={d} warm "
                                f"steps={steps} "
                                f"per_client={per_client}")})
    emit("llm_round", rows, t0=t0)


if __name__ == "__main__":
    main(sys.argv[1:])
