"""Benchmark aggregator — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run              # all benches
  PYTHONPATH=src python -m benchmarks.run regulation   # one bench

Prints ``bench/name,value,derived`` CSV rows and writes JSON to
experiments/bench/.  The roofline table is read from experiments/dryrun/
(produce it with ``python -m repro.launch.dryrun --all``, which must run
in its own process — it forces 512 host devices).
"""
from __future__ import annotations

import sys
import time
import traceback

BENCHES = ("kernels", "federated_round", "llm_round", "population",
           "regulation", "convergence", "selection", "reg_variants",
           "backends", "comm_cost", "llm_models", "theory", "roofline")


def run_one(name: str) -> bool:
    mod_name = ("benchmarks.roofline" if name == "roofline"
                else f"benchmarks.bench_{name}")
    print(f"## bench:{name}", flush=True)
    try:
        mod = __import__(mod_name, fromlist=["main"])
        mod.main()
        return True
    except Exception:
        traceback.print_exc()
        print(f"{name}/_status,FAIL,")
        return False


def main() -> None:
    todo = sys.argv[1:] or BENCHES
    t0 = time.time()
    failed = [n for n in todo if not run_one(n)]
    print(f"## total_wall_s={time.time()-t0:.0f} "
          f"ok={len(todo)-len(failed)}/{len(todo)}"
          + (f" FAILED={failed}" if failed else ""))
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
