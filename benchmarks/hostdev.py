"""Forced host-device plumbing shared by the mesh-aware benches.

Import-safe before jax: ``force_host_devices`` must run after argparse
but before the first jax touch, so this module must not import jax (or
anything that does — ``benchmarks.common`` pulls in ``repro``).
"""
from __future__ import annotations

import os


def force_host_devices(n: int) -> None:
    """Best-effort: request n host devices before jax backend init.
    A no-op when a force-count is already present in XLA_FLAGS (never
    fight an outer environment's setting)."""
    flag = f"--xla_force_host_platform_device_count={n}"
    cur = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in cur:
        os.environ["XLA_FLAGS"] = (cur + " " + flag).strip()


def clamp_to_visible(n_dev: int, bench: str) -> int:
    """Clamp a requested mesh width to the devices jax actually exposes
    (jax may already be initialized, e.g. under the run.py aggregator),
    emitting the bench's standard warning row when it does."""
    import jax                       # initialized by now — safe to touch
    if n_dev > len(jax.devices()):
        print(f"{bench}/_warn,,wanted {n_dev} devices, platform exposes "
              f"{len(jax.devices())} (jax initialized early?) — clamping")
        return len(jax.devices())
    return n_dev
