"""Paper Fig. 5/6 + Fig. 25 — objective vs round, device and server.

QFL vs LLM-QFL (±QLoRA-noised LLM reference) on the genomic task.
Reproduction claim: LLM-QFL reaches a lower objective in the same number
of rounds (regulated optimizer does more work exactly when behind).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, get_task, round_summary
from repro.core import run_experiment


def main(seed: int = 0):
    t0 = time.time()
    task = get_task("genomic", seed=seed)
    rows = []
    results = {}
    for name, kw in {
        "QFL": dict(method="qfl"),
        "LLM-QFL": dict(method="llm-qfl"),
        "LLM-QFL-LoRA": dict(method="llm-qfl", llm_steps=30),
        "LLM-QFL-qLoRA": dict(method="llm-qfl", llm_steps=15),
    }.items():
        res = run_experiment(task, n_rounds=6, maxiter0=10,
                             early_stop=False, seed=seed, **kw)
        results[name] = res
        s = round_summary(res)
        rows.append({"name": f"{name}/server_loss",
                     "value": [round(x, 4) for x in s["server_loss_series"]],
                     "derived": f"final={s['final_server_loss']:.4f}"})
        rows.append({"name": f"{name}/test_acc",
                     "value": [round(x, 3) for x in s["test_acc_series"]],
                     "derived": f"final={s['final_test_acc']:.3f}"})
        # device-2 local loss trajectory (paper Fig. 5a)
        dev2 = [round(r.client_losses[min(2, task.n_clients - 1)], 4)
                for r in res.rounds]
        rows.append({"name": f"{name}/device2_loss", "value": dev2,
                     "derived": ""})
    gain = (results["QFL"].rounds[-1].server_loss
            - results["LLM-QFL"].rounds[-1].server_loss)
    rows.append({"name": "claim/llmqfl_converges_lower",
                 "value": round(gain, 4),
                 "derived": "PASS" if gain > -0.02 else "FAIL"})
    emit("convergence", rows, t0=t0)


if __name__ == "__main__":
    main()
