"""Paper Fig. 7/8 + Figs. 21/22 — client-selection impact.

QFL vs LLM-QFL-all vs LLM-QFL-selected (10% aligned).  Claims:
(i) selected performs at least as well as all on server metrics,
(ii) selection reduces aggregation variance (Cor. VI.8.2),
(iii) LLM-QFL concentrates optimizer iterations where needed (Fig. 7:
     cumulative evals exceed the fixed budget when behind).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, get_task, round_summary
from repro.core import run_experiment


def main(seed: int = 0):
    t0 = time.time()
    task = get_task("genomic", n_clients=10, train_size=400, seed=seed)
    rows = []
    res = {}
    for name, kw in {
        "QFL": dict(method="qfl"),
        "LLM-QFL-all": dict(method="llm-qfl", select_frac=1.0),
        "LLM-QFL-selected": dict(method="llm-qfl", select_frac=0.1),
    }.items():
        r = run_experiment(task, n_rounds=5, maxiter0=10, llm_steps=15,
                           early_stop=False, seed=seed, **kw)
        res[name] = r
        s = round_summary(r)
        rows.append({"name": f"{name}/server_loss",
                     "value": [round(x, 4) for x in s["server_loss_series"]],
                     "derived": f"final={s['final_server_loss']:.4f}"})
        rows.append({"name": f"{name}/cum_evals_dev8",
                     "value": [r_.cum_evals[8] for r_ in r.rounds],
                     "derived": ""})
        if name != "QFL":
            var_ok = all(r_.var_selected <= r_.var_all + 1e-12
                         for r_ in r.rounds)
            rows.append({"name": f"{name}/variance_reduction_holds",
                         "value": var_ok,
                         "derived": "PASS" if var_ok else "FAIL"})
    sel_final = res["LLM-QFL-selected"].rounds[-1].server_loss
    all_final = res["LLM-QFL-all"].rounds[-1].server_loss
    rows.append({"name": "claim/selected_close_or_better",
                 "value": round(all_final - sel_final, 4),
                 "derived": "PASS" if sel_final <= all_final + 0.05
                 else "FAIL"})
    emit("selection", rows, t0=t0)


if __name__ == "__main__":
    main()
