"""Population-scale fused round loop vs the per-round host loop.

The fused driver (``core/fused_rounds.py``) runs R federated rounds —
local phase, FedAvg, regulation, selection, termination, loss reporting
— as ONE jitted ``lax.scan``, over a client population ``--c-pop`` with
per-round keyed cohorts of ``--c-round`` clients.  This bench times the
warm fused program against ``run_host_reference`` — the status-quo
per-round host loop (jitted local phase, host aggregation/selection,
per-client report transfers) on identical population semantics — and
reports rounds/sec for both plus the speedup (the ISSUE/ROADMAP gate:
warm fused beats the host loop at C_pop ≥ 1024, C_round = 32 on the
8-way mesh).

``--sweep-participation 0.25,0.5,1.0`` adds the convergence-vs-
participation sweep: cohort sizes ``round(frac · c_round)`` at one seed
(comparable by the driver's subsampling-inertness guarantee — a client's
draws never depend on cohort composition), reporting the final server
loss and warm rounds/sec per fraction.  ``--smoke`` shrinks everything
for CI; ``--n-devices N`` forces N host devices and shards the
population over the 'clients' mesh.

Heavy imports live inside ``main`` so the device-count flag can be set
after argparse but before the first jax touch.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.hostdev import clamp_to_visible, force_host_devices


def main(argv=()):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI workload (tiny population, 3 rounds)")
    ap.add_argument("--c-pop", type=int, default=0,
                    help="client population size (0 = 48 smoke / 1024)")
    ap.add_argument("--c-round", type=int, default=0,
                    help="per-round cohort size (0 = 8 smoke / 32)")
    ap.add_argument("--rounds", type=int, default=0,
                    help="scheduled rounds R (0 = 3 smoke / 6)")
    ap.add_argument("--maxiter", type=int, default=0,
                    help="per-client iteration budget (0 = 3 smoke / 4)")
    ap.add_argument("--optimizer", choices=["spsa", "nelder-mead"],
                    default="spsa")
    ap.add_argument("--backend", default="exact")
    ap.add_argument("--dropout", type=float, default=0.0)
    ap.add_argument("--n-devices", type=int, default=0,
                    help="force N host devices; shard the population "
                         "over the 'clients' mesh (0 = off)")
    ap.add_argument("--sweep-participation", default="",
                    help="comma list of cohort fractions of c_round "
                         "(e.g. 0.25,0.5,1.0): final-loss-vs-"
                         "participation sweep at one seed")
    ap.add_argument("--train-size", type=int, default=0,
                    help="TOTAL training examples across the population "
                         "(0 = 4 per client)")
    args = ap.parse_args(list(argv))

    if args.n_devices > 1 and "jax" not in sys.modules:
        force_host_devices(args.n_devices)

    import jax
    import numpy as np

    from benchmarks.common import emit, get_task
    from repro.core.fused_rounds import FusedRoundDriver
    from repro.quantum import backends as backend_mod
    from repro.quantum import qnn

    if args.backend not in backend_mod.BACKENDS:
        ap.error(f"--backend must be one of "
                 f"{sorted(backend_mod.BACKENDS)}")
    n_dev = clamp_to_visible(args.n_devices, "population")

    c_pop = args.c_pop or (48 if args.smoke else 1024)
    c_round = args.c_round or (8 if args.smoke else 32)
    rounds = args.rounds or (3 if args.smoke else 6)
    maxiter = args.maxiter or (3 if args.smoke else 4)
    c_round = min(c_round, c_pop)
    if n_dev > 1:
        c_round = max(n_dev, (c_round // n_dev) * n_dev)
    train = args.train_size or 4 * c_pop

    task = get_task("genomic", n_clients=c_pop, train_size=train)
    spec = qnn.QNNSpec("vqc", n_qubits=4, n_classes=task.n_classes)
    backend = backend_mod.get(args.backend)
    theta0 = np.asarray(spec.init_params(jax.random.PRNGKey(0)),
                        np.float64)

    def make_driver(cr):
        return FusedRoundDriver(
            task, spec, backend, optimizer=args.optimizer, seed=0,
            use_llm=False, maxiter0=maxiter, n_rounds=rounds,
            early_stop=False, c_round=cr, dropout=args.dropout,
            n_devices=n_dev if n_dev > 1 else None)

    t0 = time.time()
    rows = []
    driver = make_driver(c_round)

    tc = time.perf_counter()
    out = driver.run(theta0)                       # compile + run
    cold = time.perf_counter() - tc
    tw = time.perf_counter()
    out = driver.run(theta0)                       # warm
    warm = time.perf_counter() - tw
    tag = (f"c_pop={c_pop} c_round={c_round} rounds={rounds} "
           f"maxiter={maxiter} optimizer={args.optimizer} "
           f"backend={args.backend} n_devices={n_dev or 1} "
           f"dropout={args.dropout}")
    rows.append({"name": "fused_rounds_per_s",
                 "value": f"{rounds / warm:.2f}",
                 "derived": (f"{tag} warm={warm:.3f}s cold={cold:.2f}s "
                             f"final_loss={out.server_loss[-1]:.6f}")})

    th = time.perf_counter()
    href = driver.run_host_reference(theta0)       # warms its round jit
    th = time.perf_counter()
    href = driver.run_host_reference(theta0)       # warm
    host = time.perf_counter() - th
    gap = float(np.abs(out.theta_g
                       - href.theta_g.astype(np.float32)).max())
    rows.append({"name": "host_rounds_per_s",
                 "value": f"{rounds / host:.2f}",
                 "derived": (f"per-round host loop warm={host:.3f}s "
                             f"final_loss={href.server_loss[-1]:.6f}")})
    rows.append({"name": "fused_speedup",
                 "value": f"{host / warm:.2f}",
                 "derived": (f"warm fused vs per-round host loop "
                             f"dtheta={gap:.2e} target>1x")})

    if args.sweep_participation:
        fracs = [float(f) for f in args.sweep_participation.split(",")
                 if f]
        for frac in fracs:
            cr = max(1, int(round(frac * c_round)))
            if n_dev > 1:
                cr = max(n_dev, (cr // n_dev) * n_dev)
            d = make_driver(cr)
            d.run(theta0)                          # compile
            ts = time.perf_counter()
            o = d.run(theta0)                      # warm
            w = time.perf_counter() - ts
            rows.append({
                "name": f"participation_{frac:g}",
                "value": f"{o.server_loss[-1]:.6f}",
                "derived": (f"c_round={cr}/{c_pop} final_server_loss "
                            f"rounds_per_s={rounds / w:.2f} "
                            f"test_acc={o.test_acc[-1]:.4f}")})

    emit("population", rows, t0=t0)


if __name__ == "__main__":
    main(sys.argv[1:])
