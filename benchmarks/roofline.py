"""Roofline report — reads the dry-run artifacts and prints the per-
(arch × shape × mesh) three-term roofline table (EXPERIMENTS.md §Roofline).

Run ``python -m repro.launch.dryrun --all`` first (separate process: it
forces 512 host devices).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

DRY_DIR = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def load(tag: str = "baseline"):
    recs = []
    for f in sorted(DRY_DIR.glob(f"*_{tag}.json")):
        d = json.loads(f.read_text())
        recs.append(d)
    return recs


def fmt_row(d):
    r = d.get("roofline", {})
    m = d.get("memory", {})
    mf = d.get("model_flops", 0.0)
    hw = d.get("cost", {}).get("flops_per_device", 0.0)
    util = mf / (hw * 256) if hw else 0.0     # vs single-pod chips
    return (f"{d['arch']:26s} {d['shape']:12s} {d['mesh']:6s} "
            f"{d['status']:4s} "
            f"c={r.get('compute_s', 0):9.2e} "
            f"m={r.get('memory_s', 0):9.2e} "
            f"x={r.get('collective_s', 0):9.2e} "
            f"dom={r.get('dominant', '-'):10s} "
            f"peak={m.get('peak_bytes_per_device', 0)/2**30:7.2f}GiB")


HILLCLIMB = [
    ("llama3-405b", "train_4k", ["faithful", "opt1", "opt2", "opt4",
                                 "opt5"]),
    ("kimi-k2-1t-a32b", "train_4k", ["faithful", "opt1", "opt2", "opt3",
                                     "opt5", "opt7"]),
    ("jamba-1.5-large-398b", "prefill_32k", ["faithful", "opt1", "opt2",
                                             "opt3", "opt5"]),
]


def main():
    t0 = time.time()
    for tag in ("faithful", "optimized"):
        recs = load(tag)
        ok = sum(1 for r in recs if r["status"] == "ok")
        print(f"# roofline table ({tag}): {ok}/{len(recs)} ok")
        for d in recs:
            print("roofline/" + fmt_row(d))
        doms = {}
        for d in recs:
            if d["status"] == "ok":
                doms[d["roofline"]["dominant"]] = \
                    doms.get(d["roofline"]["dominant"], 0) + 1
        print(f"roofline/_dominant_histogram[{tag}],{doms},")
    print("# hillclimb ladders (§Perf)")
    for arch, shape, tags in HILLCLIMB:
        for tag in tags:
            f = DRY_DIR / f"{arch}_{shape}_single_{tag}.json"
            if f.exists():
                print("perf/" + fmt_row(json.loads(f.read_text())
                                        ) + f" tag={tag}")
    print(f"roofline/_wall_s,{time.time()-t0:.1f},")


if __name__ == "__main__":
    main()
