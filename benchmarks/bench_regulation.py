"""Paper Fig. 4 — impact of regulation on the optimizer.

Tracks device-0's maxiter and loss-ratio trajectory across rounds for
QFL / LLM-QFL-all / LLM-QFL-selected.  Expected reproduction: QFL's
maxiter stays constant; LLM-QFL variants adapt after round 2, and the
ratio decreases as the quantum model converges toward the LLM benchmark.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, get_task
from repro.core import run_experiment


def main(seed: int = 0):
    t0 = time.time()
    task = get_task("genomic", seed=seed)
    rows = []
    runs = {
        "QFL": dict(method="qfl"),
        "LLM-QFL-all": dict(method="llm-qfl", select_frac=1.0),
        "LLM-QFL-selected": dict(method="llm-qfl", select_frac=0.2),
    }
    adaptive = {}
    for name, kw in runs.items():
        res = run_experiment(task, n_rounds=6, maxiter0=10, llm_steps=20,
                             early_stop=False, seed=seed, **kw)
        mx = [r.maxiters[0] for r in res.rounds]
        ratio = [round(r.ratios[0], 3) for r in res.rounds]
        # a device "adapted" if its maxiter ever left maxiter0 (regulation
        # fires only for devices BEHIND their LLM reference — Alg. 1 l.12)
        adaptive[name] = sum(
            1 for i in range(task.n_clients)
            if len({r.maxiters[i] for r in res.rounds}) > 1)
        rows.append({"name": f"{name}/maxiter_dev0", "value": mx,
                     "derived": "constant" if len(set(mx)) == 1
                     else "adaptive"})
        rows.append({"name": f"{name}/ratio_dev0", "value": ratio,
                     "derived": f"final={ratio[-1]}"})
        rows.append({"name": f"{name}/n_adaptive_devices",
                     "value": adaptive[name],
                     "derived": f"of {task.n_clients}"})
    rows.append({
        "name": "claim/qfl_static_vs_llmqfl_adaptive",
        "value": {k: v for k, v in adaptive.items()},
        "derived": "PASS" if adaptive["QFL"] == 0
        and (adaptive["LLM-QFL-all"] > 0
             or adaptive["LLM-QFL-selected"] > 0) else "FAIL"})
    emit("regulation", rows, t0=t0)


if __name__ == "__main__":
    main()
