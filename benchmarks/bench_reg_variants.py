"""Paper Fig. 20 (App. F) — choice of maxiter regulation variant.

Runs Incr/Ada/Log/Dyn on the same task; reports convergence and total
optimizer spend.  Claim: all variants adapt (non-constant maxiter) and the
logarithmic variant spends the fewest iterations for comparable loss.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, get_task
from repro.core import run_experiment
from repro.core.regulation import VARIANTS


def main(seed: int = 0):
    t0 = time.time()
    task = get_task("genomic", seed=seed)
    rows, spend = [], {}
    for v in VARIANTS:
        res = run_experiment(task, method="llm-qfl", regulation=v,
                             n_rounds=5, maxiter0=10, llm_steps=15,
                             early_stop=False, seed=seed)
        total = sum(res.rounds[-1].cum_evals)
        spend[v] = total
        rows.append({
            "name": f"LLM-QFL-{v}",
            "value": f"final_loss={res.rounds[-1].server_loss:.4f},"
                     f"total_evals={total}",
            "derived": f"maxiter_dev0={[r.maxiters[0] for r in res.rounds]}"})
    rows.append({"name": "claim/variants_differ",
                 "value": spend,
                 "derived": "PASS" if len(set(spend.values())) > 1
                 else "FAIL"})
    emit("reg_variants", rows, t0=t0)


if __name__ == "__main__":
    main()
