"""Cor. VI.8 — empirical vs theoretical efficiency ratios.

1. Adaptive step-size efficiency:  T_QFL / T_LLM-QFL ≥ E[K_i^t] / K.
   We measure rounds-to-threshold for both methods and the realized
   mean adaptive iteration count.
2. Variance reduction: Var(∇F_selected) ≤ (1 − k/N)·Var(∇F_all), checked
   per round on the aligned-selection loss distances.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, get_task
from repro.core import run_experiment


def rounds_to(res, thresh):
    for r in res.rounds:
        if r.server_loss <= thresh:
            return r.t
    return len(res.rounds) + 1          # did not reach


def main(seed: int = 0):
    t0 = time.time()
    task = get_task("genomic", n_clients=8, train_size=320, seed=seed)
    K = 8
    qfl = run_experiment(task, method="qfl", n_rounds=8, maxiter0=K,
                         early_stop=False, seed=seed)
    llm = run_experiment(task, method="llm-qfl", n_rounds=8, maxiter0=K,
                         select_frac=0.25, llm_steps=15,
                         early_stop=False, seed=seed)
    rows = []

    # 1. step-size efficiency
    mean_k = float(np.mean([np.mean(r.maxiters) for r in llm.rounds]))
    thresh = max(qfl.rounds[-1].server_loss, llm.rounds[-1].server_loss)
    t_qfl, t_llm = rounds_to(qfl, thresh), rounds_to(llm, thresh)
    lhs = t_qfl / max(t_llm, 1)
    rhs = mean_k / K
    rows.append({"name": "cor1/adaptive_step_efficiency",
                 "value": f"T_qfl={t_qfl},T_llm={t_llm},"
                          f"E[K]={mean_k:.1f},K={K}",
                 "derived": f"T ratio={lhs:.2f} vs E[K]/K={rhs:.2f} "
                            f"({'consistent' if lhs >= 1.0 or rhs <= 1.05 else 'violated'})"})

    # 2. variance reduction with k/N = 0.25
    frac_bound = 1.0 - 0.25
    ok, ratios = True, []
    for r in llm.rounds:
        if r.var_all > 1e-12:
            ratio = r.var_selected / r.var_all
            ratios.append(round(ratio, 3))
            ok &= ratio <= frac_bound + 0.25   # Markov-style, slack for N=8
    rows.append({"name": "cor2/variance_reduction",
                 "value": ratios,
                 "derived": f"bound=(1-k/N)={frac_bound:.2f} "
                            f"{'PASS' if ok else 'FAIL'}"})

    # 3. convergence O(1/T): server loss roughly decreasing
    s = [r.server_loss for r in llm.rounds]
    rows.append({"name": "thm1/loss_trend", "value": [round(x, 4) for x in s],
                 "derived": f"net_drop={s[0]-s[-1]:.4f}"})
    emit("theory", rows, t0=t0)


if __name__ == "__main__":
    main()
