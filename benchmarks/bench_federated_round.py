"""Sequential vs batched federated round engine on the 5-client VQC task.

Times ``run_experiment`` end-to-end for both engines on the same task and
config (method="qfl" so the one-time LLM fine-tune does not dilute the
round timing) and emits per-round wall-times, the speedup, and the
convergence gap — the acceptance gate is batched ≥5× sequential at
matched convergence.

``--optimizer`` selects the update law both paths run: "spsa" or
"nelder-mead" (the paper's default, batched via speculative simplex
candidate evaluation).  ``--backend`` picks the quantum backend — the
noisy ones run keyed finite-shot sampling on the fast path, so the
speedup/parity gate covers Table I's shot-noise setting too.  ``--smoke``
shrinks the workload for CI; ``--engine X`` runs one engine only (for
profiling).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit, get_task
from repro.core.orchestrator import run_experiment
from repro.quantum.backends import BACKENDS


def _run(task, engine: str, *, rounds: int, maxiter: int,
         optimizer: str = "spsa", backend: str = "exact"):
    t0 = time.perf_counter()
    res = run_experiment(task, method="qfl", optimizer=optimizer,
                         engine=engine, n_rounds=rounds, maxiter0=maxiter,
                         early_stop=False, backend=backend)
    wall = time.perf_counter() - t0
    return wall, res


def main(argv=()):
    # default () — not None — so the run.py aggregator's ``main()`` call
    # never re-parses the aggregator's own sys.argv
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI workload (fewer rounds/iters/examples)")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--maxiter", type=int, default=None)
    ap.add_argument("--clients", type=int, default=5)
    ap.add_argument("--engine", choices=["sequential", "batched", "both"],
                    default="both")
    ap.add_argument("--optimizer", choices=["spsa", "nelder-mead"],
                    default="spsa")
    ap.add_argument("--backend", choices=sorted(BACKENDS),
                    default="exact",
                    help="quantum backend; noisy ones (fake/aersim/real) "
                         "run keyed finite-shot sampling in both engines")
    args = ap.parse_args(list(argv))

    rounds = args.rounds or (2 if args.smoke else 3)
    maxiter = args.maxiter or (5 if args.smoke else 25)
    train = 120 if args.smoke else 250
    task = get_task("genomic", n_clients=args.clients, train_size=train)

    t0 = time.time()
    rows = []
    results = {}
    for engine in (("sequential", "batched") if args.engine == "both"
                   else (args.engine,)):
        wall, res = _run(task, engine, rounds=rounds, maxiter=maxiter,
                         optimizer=args.optimizer, backend=args.backend)
        results[engine] = (wall, res)
        rows.append({
            "name": f"{engine}_round_s",
            "value": f"{wall / rounds:.3f}",
            "derived": (f"optimizer={args.optimizer} "
                        f"backend={args.backend} total={wall:.2f}s "
                        f"rounds={rounds} maxiter={maxiter} "
                        f"clients={args.clients} "
                        f"final_loss={res.rounds[-1].server_loss:.6f}")})

    if len(results) == 2:
        w_seq, r_seq = results["sequential"]
        w_bat, r_bat = results["batched"]
        gap = max(abs(a.server_loss - b.server_loss)
                  for a, b in zip(r_seq.rounds, r_bat.rounds))
        dtheta = float(np.abs(r_seq.theta_g - r_bat.theta_g).max())
        rows.append({
            "name": "speedup",
            "value": f"{w_seq / w_bat:.2f}",
            "derived": (f"loss_gap={gap:.2e} dtheta={dtheta:.2e} "
                        f"target>=5x")})
        # warm engine: the compiled round program is cached module-wide,
        # so a second run isolates steady-state round wall-time (the
        # sequential path has no warm state — it re-traces every round
        # by construction, which is precisely its bottleneck)
        w_warm, _ = _run(task, "batched", rounds=rounds, maxiter=maxiter,
                         optimizer=args.optimizer, backend=args.backend)
        rows.append({
            "name": "batched_warm_round_s",
            "value": f"{w_warm / rounds:.3f}",
            "derived": (f"speedup_vs_seq_round="
                        f"{w_seq / w_warm:.1f}x total={w_warm:.2f}s")})
    emit("federated_round", rows, t0=t0)


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
