"""Sequential vs batched federated round engine on the 5-client VQC task.

Times ``run_experiment`` end-to-end for both engines on the same task and
config (method="qfl" so the one-time LLM fine-tune does not dilute the
round timing) and emits per-round wall-times, the speedup, and the
convergence gap — the acceptance gate is batched ≥5× sequential at
matched convergence.

``--optimizer`` selects the update law both paths run: "spsa" or
"nelder-mead" (the paper's default, batched via speculative simplex
candidate evaluation).  ``--backend`` picks the quantum backend — the
noisy ones run keyed finite-shot sampling on the fast path, so the
speedup/parity gate covers Table I's shot-noise setting too.  ``--smoke``
shrinks the workload for CI; ``--engine X`` runs one engine only (for
profiling).

``--n-devices N`` forces N host devices (setting
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax
initializes — a no-op if jax is already live, e.g. under the run.py
aggregator, in which case the available device count is used) and runs
the batched engine on an N-wide 'clients' mesh.  ``--sweep-clients
8,16,32,64`` adds the ROADMAP scaling sweep: for each client count C the
batched engine runs once on a single device and once on the mesh,
reporting round wall-time vs device count.

Heavy imports live inside ``main`` so the device-count flag can be set
after argparse but before the first jax touch.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.hostdev import clamp_to_visible, force_host_devices


def main(argv=()):
    # default () — not None — so the run.py aggregator's ``main()`` call
    # never re-parses the aggregator's own sys.argv
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI workload (fewer rounds/iters/examples)")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--maxiter", type=int, default=None)
    ap.add_argument("--clients", type=int, default=5)
    ap.add_argument("--engine", choices=["sequential", "batched", "both"],
                    default="both")
    ap.add_argument("--optimizer", choices=["spsa", "nelder-mead"],
                    default="spsa")
    ap.add_argument("--backend", default="exact",
                    help="quantum backend; noisy ones (fake/aersim/real) "
                         "run keyed finite-shot sampling in both engines")
    ap.add_argument("--n-devices", type=int, default=0,
                    help="force N host devices and run the batched "
                         "engine on an N-wide 'clients' mesh (0 = off)")
    ap.add_argument("--sweep-clients", default="",
                    help="comma list of client counts (e.g. 8,16,32,64): "
                         "bench batched round time 1 device vs the mesh")
    ap.add_argument("--sweep-qubits", default="",
                    help="comma list of qubit counts (e.g. 4,6,8,10): "
                         "qubit-scaling sweep through the batched engine "
                         "(statevector cost doubles per qubit)")
    ap.add_argument("--train-size", type=int, default=0,
                    help="TOTAL training examples, split across clients "
                         "(0 = 120 smoke / 250 full); raise it with "
                         "--sweep-clients so per-client work doesn't "
                         "shrink as C grows")
    args = ap.parse_args(list(argv))

    if args.n_devices > 1 and "jax" not in sys.modules:
        force_host_devices(args.n_devices)

    import jax
    import numpy as np

    from benchmarks.common import emit, get_task
    from repro.core.orchestrator import run_experiment
    from repro.quantum.backends import BACKENDS

    if args.backend not in BACKENDS:
        ap.error(f"--backend must be one of {sorted(BACKENDS)}")
    n_dev = clamp_to_visible(args.n_devices, "federated_round")

    def _run(engine, *, rounds, maxiter, clients=args.clients,
             devices=None, n_qubits=4):
        task = get_task("genomic", n_clients=clients,
                        train_size=args.train_size
                        or (120 if args.smoke else 250),
                        **({"n_features": n_qubits} if n_qubits != 4
                           else {}))
        t0 = time.perf_counter()
        res = run_experiment(
            task, method="qfl", optimizer=args.optimizer, engine=engine,
            n_rounds=rounds, maxiter0=maxiter, early_stop=False,
            backend=args.backend, n_qubits=n_qubits,
            n_devices=devices if engine == "batched" else None)
        return time.perf_counter() - t0, res

    rounds = args.rounds or (2 if args.smoke else 3)
    maxiter = args.maxiter or (5 if args.smoke else 25)

    t0 = time.time()
    rows = []
    results = {}
    for engine in (("sequential", "batched") if args.engine == "both"
                   else (args.engine,)):
        devices = n_dev if n_dev > 1 else None
        wall, res = _run(engine, rounds=rounds, maxiter=maxiter,
                         devices=devices)
        results[engine] = (wall, res)
        rows.append({
            "name": f"{engine}_round_s",
            "value": f"{wall / rounds:.3f}",
            "derived": (f"optimizer={args.optimizer} "
                        f"backend={args.backend} total={wall:.2f}s "
                        f"rounds={rounds} maxiter={maxiter} "
                        f"clients={args.clients} "
                        + (f"n_devices={devices} "
                           if engine == "batched" and devices else "")
                        + f"final_loss={res.rounds[-1].server_loss:.6f}")})

    if len(results) == 2:
        w_seq, r_seq = results["sequential"]
        w_bat, r_bat = results["batched"]
        gap = max(abs(a.server_loss - b.server_loss)
                  for a, b in zip(r_seq.rounds, r_bat.rounds))
        dtheta = float(np.abs(r_seq.theta_g - r_bat.theta_g).max())
        rows.append({
            "name": "speedup",
            "value": f"{w_seq / w_bat:.2f}",
            "derived": (f"loss_gap={gap:.2e} dtheta={dtheta:.2e} "
                        f"target>=5x")})
        # warm engine: the compiled round program is cached module-wide,
        # so a second run isolates steady-state round wall-time (the
        # sequential path has no warm state — it re-traces every round
        # by construction, which is precisely its bottleneck)
        w_warm, _ = _run("batched", rounds=rounds, maxiter=maxiter,
                         devices=n_dev if n_dev > 1 else None)
        rows.append({
            "name": "batched_warm_round_s",
            "value": f"{w_warm / rounds:.3f}",
            "derived": (f"speedup_vs_seq_round="
                        f"{w_seq / w_warm:.1f}x total={w_warm:.2f}s")})

    if args.sweep_clients:
        # ROADMAP scaling sweep: batched round wall-time vs device count
        # at growing client counts.  Cold+warm per point; the warm number
        # is the steady-state round time the mesh is judged on.
        sweep = [int(c) for c in args.sweep_clients.split(",") if c]
        mesh_w = n_dev if n_dev > 1 else len(jax.devices())
        for C in sweep:
            for devices in (None, mesh_w) if mesh_w > 1 else (None,):
                _run("batched", rounds=1, maxiter=maxiter, clients=C,
                     devices=devices)                        # compile
                wall, res = _run("batched", rounds=rounds,
                                 maxiter=maxiter, clients=C,
                                 devices=devices)            # warm
                d = devices or 1
                rows.append({
                    "name": f"sweep_c{C}_d{d}_round_s",
                    "value": f"{wall / rounds:.3f}",
                    "derived": (f"clients={C} n_devices={d} warm "
                                f"optimizer={args.optimizer} "
                                f"final_loss="
                                f"{res.rounds[-1].server_loss:.6f}")})

    if args.sweep_qubits:
        # ROADMAP scale-knobs sweep: statevector cost doubles per qubit,
        # so this is where the tape executor's kernel choices show up.
        # Batched engine only (the scaling target); cold+warm per point.
        qsweep = [int(q) for q in args.sweep_qubits.split(",") if q]
        devices = n_dev if n_dev > 1 else None
        for q in qsweep:
            _run("batched", rounds=1, maxiter=maxiter,
                 devices=devices, n_qubits=q)                 # compile
            wall, res = _run("batched", rounds=rounds, maxiter=maxiter,
                             devices=devices, n_qubits=q)     # warm
            rows.append({
                "name": f"sweep_q{q}_round_s",
                "value": f"{wall / rounds:.3f}",
                "derived": (f"n_qubits={q} warm "
                            f"n_devices={devices or 1} "
                            f"optimizer={args.optimizer} "
                            f"final_loss="
                            f"{res.rounds[-1].server_loss:.6f}")})
    emit("federated_round", rows, t0=t0)


if __name__ == "__main__":
    main(sys.argv[1:])
