"""Paper Fig. 24 + App. J — base-LLM comparison.

The paper compares LLaMA-3.2-1B / GPT-2 / DeepSeek-7B as the fine-tuned
reference.  We instantiate each *family proxy* at CPU scale (layers/width
scaled, same family hyper-shape ratios) plus tiny-llm, and report round-1
fine-tune F1 and its effect on device convergence.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import emit, get_task
from repro.configs import paper_models
from repro.core import run_experiment
from repro.core import llm_client as lc


def _cpu_proxy(cfg, vocab):
    """Scale a paper LLM config to CPU size, keeping family ratios."""
    d = 128
    return dataclasses.replace(
        cfg, n_layers=2, d_model=d, n_heads=4,
        n_kv_heads=max(1, 4 * cfg.n_kv_heads // cfg.n_heads),
        head_dim=d // 4, d_ff=int(d * cfg.d_ff / cfg.d_model),
        vocab_size=vocab)


def main(seed: int = 0):
    t0 = time.time()
    task = get_task("genomic", n_clients=3, train_size=150, seed=seed)
    rows = []
    # monkey-patch proxy configs into the llm-config resolver
    base_cfgs = {
        "llama3.2-1b": paper_models.LLAMA32_1B,
        "gpt2": paper_models.GPT2,
        "deepseek-llm-7b-base": paper_models.DEEPSEEK_7B,
    }
    orig = lc.task_llm_config
    for name, cfg in base_cfgs.items():
        proxy = _cpu_proxy(cfg, task.vocab_size)
        lc.task_llm_config = (
            lambda bn, v, s, _p=proxy: dataclasses.replace(_p, vocab_size=v))
        try:
            res = run_experiment(task, method="llm-qfl", n_rounds=3,
                                 maxiter0=8, llm_steps=25, early_stop=False,
                                 seed=seed)
        finally:
            lc.task_llm_config = orig
        rows.append({
            "name": name,
            "value": f"llm_f1={np.mean(res.llm_f1):.3f},"
                     f"llm_loss={np.mean(res.llm_losses):.3f},"
                     f"final_dev_loss="
                     f"{np.mean(res.rounds[-1].client_losses):.4f}",
            "derived": f"ft_time={res.llm_finetune_time_s:.1f}s"})
    emit("llm_models", rows, t0=t0)


if __name__ == "__main__":
    main()
