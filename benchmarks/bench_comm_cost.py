"""Paper Fig. 26 — communication cost: QFL vs LLM-QFL vs LLM-QFL-QLoRA.

Claims: (i) per-round LLM-QFL costs MORE wall-time than QFL when all
rounds run (regulated maxiter does extra iterations), (ii) early stopping
recovers the total-cost advantage, (iii) QLoRA (faster fine-tune) tracks
plain QFL's per-round cost more closely.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, get_task
from repro.core import run_experiment


def main(seed: int = 0):
    t0 = time.time()
    task = get_task("genomic", n_clients=4, train_size=200, seed=seed)
    rows, total = [], {}
    for name, kw in {
        "QFL": dict(method="qfl", early_stop=False),
        "LLM-QFL": dict(method="llm-qfl", early_stop=False),
        "LLM-QFL-earlystop": dict(method="llm-qfl", early_stop=True,
                                  epsilon=5e-2),
        "LLM-QFL-QLoRA": dict(method="llm-qfl", llm_steps=8,
                              early_stop=False),
    }.items():
        res = run_experiment(task, backend="aersim", n_rounds=6,
                             maxiter0=8, seed=seed,
                             **{**dict(llm_steps=15), **kw})
        per_round = [round(r.comm_time_s, 2) for r in res.rounds]
        tot = sum(r.comm_time_s for r in res.rounds)
        total[name] = tot
        rows.append({"name": f"{name}/comm_per_round", "value": per_round,
                     "derived": f"total={tot:.1f}s rounds={len(res.rounds)}"})
    rows.append({
        "name": "claim/llmqfl_per_round_costlier_but_earlystop_wins",
        "value": {k: round(v, 1) for k, v in total.items()},
        "derived": "PASS" if (total["LLM-QFL"] >= total["QFL"] * 0.8
                              and total["LLM-QFL-earlystop"]
                              <= total["LLM-QFL"]) else "FAIL"})
    emit("comm_cost", rows, t0=t0)


if __name__ == "__main__":
    main()
