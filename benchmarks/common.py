"""Shared benchmark harness: tasks, timing, CSV/JSON emission."""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, List

from repro.data.tasks import build_task

OUT_DIR = Path(__file__).resolve().parent.parent / "experiments" / "bench"

_TASK_CACHE: Dict = {}


def get_task(name: str = "genomic", *, n_clients: int = 5,
             train_size: int = 250, seed: int = 0, **kw):
    key = (name, n_clients, train_size, seed, tuple(sorted(kw.items())))
    if key not in _TASK_CACHE:
        _TASK_CACHE[key] = build_task(
            name, n_clients=n_clients, train_size=train_size,
            test_size=100, val_size=60, seed=seed, **kw)
    return _TASK_CACHE[key]


def emit(bench: str, rows: List[dict], *, t0: float = None):
    """Print CSV rows and persist JSON."""
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    (OUT_DIR / f"{bench}.json").write_text(json.dumps(rows, indent=1))
    for r in rows:
        derived = r.get("derived", "")
        val = r.get("value", "")
        print(f"{bench}/{r['name']},{val},{derived}")
    if t0 is not None:
        print(f"{bench}/_wall_s,{time.time()-t0:.1f},")


def round_summary(res) -> dict:
    return {
        "rounds": len(res.rounds),
        "final_server_loss": res.rounds[-1].server_loss,
        "final_test_acc": res.rounds[-1].server_test_acc,
        "server_loss_series": [r.server_loss for r in res.rounds],
        "test_acc_series": [r.server_test_acc for r in res.rounds],
        "maxiter_series": [r.maxiters for r in res.rounds],
        "cum_evals_final": res.rounds[-1].cum_evals,
        "terminated_early": res.terminated_early,
    }
