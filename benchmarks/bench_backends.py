"""Paper Table I + Figs. 9/10/17 — simulators vs (emulated) real QPU.

Runs the same small federated experiment on exact / fake / aersim / real
backends and reports device/server accuracy and communication time.
Reproduction claims: comm-time ordering Fake < AerSim < Real (~4–8×
slower end-to-end for Real, queue-dominated), noisy-backend accuracy ≤
exact, and — since keyed finite-shot sampling landed — that shot noise
is *live*: the noisy scenarios re-run with ``shots_override=0``
(channel-only ablation) must diverge from the finite-shot run.

``--engine batched`` runs the noisy scenarios through the fused round
engine (shot sampling inside the jitted round program); ``--smoke``
shrinks the workload for CI.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit, get_task
from repro.core import run_experiment
from repro.quantum import backends


def main(argv=()):
    # default () — not None — so the run.py aggregator's ``main()`` call
    # never re-parses the aggregator's own sys.argv
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI workload (fewer rounds/iters/steps)")
    ap.add_argument("--engine", choices=["sequential", "batched"],
                    default="sequential")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(list(argv))

    t0 = time.time()
    n_rounds = 2 if args.smoke else 3
    maxiter0 = 3 if args.smoke else 5
    llm_steps = 6 if args.smoke else 12
    train = 120 if args.smoke else 200
    task = get_task("genomic", n_clients=4, train_size=train,
                    seed=args.seed)
    kw = dict(method="llm-qfl", engine=args.engine, n_rounds=n_rounds,
              maxiter0=maxiter0, llm_steps=llm_steps, early_stop=False,
              seed=args.seed)
    rows, comm, losses = [], {}, {}
    for name in ("exact", "fake", "aersim", "real"):
        res = run_experiment(task, backend=name, **kw)
        total_comm = sum(r.comm_time_s for r in res.rounds)
        comm[name] = total_comm
        losses[name] = res.series("server_loss")
        last = res.rounds[-1]
        dev_loss = float(np.mean(last.client_losses))
        rows.append({
            "name": f"{name}",
            "value": f"val_acc={last.server_val_acc:.3f},"
                     f"test_acc={last.server_test_acc:.3f},"
                     f"dev_loss={dev_loss:.3f},comm_s={total_comm:.1f}",
            "derived": f"engine={args.engine}"})
    ordering = comm["fake"] < comm["aersim"] < comm["real"]
    rows.append({"name": "claim/table1_comm_ordering",
                 "value": {k: round(v, 1) for k, v in comm.items()},
                 "derived": "PASS" if ordering else "FAIL"})

    # shot noise must fire: the channel-only ablation of the fake
    # backend (shots_override=0) has to leave the finite-shot trajectory
    ablation = run_experiment(task, backend="fake", shots_override=0,
                              **kw)
    shot_gap = max(abs(a - b) for a, b in
                   zip(losses["fake"], ablation.series("server_loss")))
    rows.append({"name": "claim/shot_sampling_live",
                 "value": f"{shot_gap:.2e}",
                 "derived": "PASS" if shot_gap > 0 else "FAIL"})
    emit("backends", rows, t0=t0)


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
