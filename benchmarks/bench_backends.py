"""Paper Table I + Figs. 9/10/17 — simulators vs (emulated) real QPU.

Runs the same small federated experiment on fake / aersim / real backends
and reports device/server accuracy and communication time.  Reproduction
claims: comm-time ordering Fake < AerSim < Real (~4–8× slower end-to-end
for Real, queue-dominated), and noisy-backend accuracy ≤ exact.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, get_task
from repro.core import run_experiment
from repro.quantum import backends


def main(seed: int = 0):
    t0 = time.time()
    task = get_task("genomic", n_clients=4, train_size=200, seed=seed)
    rows, comm = [], {}
    for name in ("exact", "fake", "aersim", "real"):
        res = run_experiment(task, method="llm-qfl", backend=name,
                             n_rounds=3, maxiter0=5, llm_steps=12,
                             early_stop=False, seed=seed)
        total_comm = sum(r.comm_time_s for r in res.rounds)
        comm[name] = total_comm
        last = res.rounds[-1]
        dev_loss = float(np.mean(last.client_losses))
        rows.append({
            "name": f"{name}",
            "value": f"val_acc={last.server_val_acc:.3f},"
                     f"test_acc={last.server_test_acc:.3f},"
                     f"dev_loss={dev_loss:.3f},comm_s={total_comm:.1f}",
            "derived": ""})
    ordering = comm["fake"] < comm["aersim"] < comm["real"]
    rows.append({"name": "claim/table1_comm_ordering",
                 "value": {k: round(v, 1) for k, v in comm.items()},
                 "derived": "PASS" if ordering else "FAIL"})
    emit("backends", rows, t0=t0)


if __name__ == "__main__":
    main()
