"""Kernel micro-benchmarks: Pallas(interpret) vs jnp-oracle correctness at
benchmark shapes + oracle wall-time (CPU timings are for the jnp path —
TPU timings come from the dry-run roofline, not this container).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ops, ref
from repro.peft.lora import quantize

KEY = jax.random.PRNGKey(0)


def _time(fn, *args, n=5):
    jax.block_until_ready(fn(*args))     # single warmup call (jit compile)
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def main():
    t0 = time.time()
    rows = []
    ks = jax.random.split(KEY, 8)

    M, K, N, r = 512, 1024, 1024, 16
    x = jax.random.normal(ks[0], (M, K), jnp.float32)
    w = jax.random.normal(ks[1], (K, N), jnp.float32) * 0.02
    a = jax.random.normal(ks[2], (K, r), jnp.float32) * 0.02
    b = jax.random.normal(ks[3], (r, N), jnp.float32) * 0.02
    err = float(jnp.abs(ops.lora_matmul(x, w, a, b, scale=2.0)
                        - ref.lora_matmul(x, w, a, b, 2.0)).max())
    us = _time(jax.jit(lambda *t: ref.lora_matmul(*t, 2.0)), x, w, a, b)
    rows.append({"name": "lora_matmul", "value": f"{us:.0f}",
                 "derived": f"max_err={err:.2e} shape={M}x{K}x{N}r{r}"})

    packed, scales = quantize(w, 64)
    err = float(jnp.abs(ops.int4_matmul(x, packed, scales)
                        - ref.int4_matmul(x, packed, scales, 64)).max())
    us = _time(jax.jit(lambda *t: ref.int4_matmul(*t, 64)),
               x, packed, scales)
    rows.append({"name": "int4_matmul", "value": f"{us:.0f}",
                 "derived": f"max_err={err:.2e}"})

    t = jax.nn.softmax(jax.random.normal(ks[4], (4096, 32)), -1)
    z = jax.random.normal(ks[5], (4096, 32))
    err = float(jnp.abs(ops.distill_kl(t, z) - ref.distill_kl(t, z)).max())
    us = _time(jax.jit(ref.distill_kl), t, z)
    rows.append({"name": "distill_kl", "value": f"{us:.0f}",
                 "derived": f"max_err={err:.2e}"})

    B, H, S, D = 1, 4, 512, 64
    q = jax.random.normal(ks[6], (B, H, S, D), jnp.float32)
    k2 = jax.random.normal(ks[7], (B, H, S, D), jnp.float32)
    v = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
    err = float(jnp.abs(ops.flash_attention(q, k2, v)
                        - ref.flash_attention(q, k2, v)).max())
    us = _time(jax.jit(lambda *t: ref.flash_attention(*t)), q, k2, v)
    rows.append({"name": "flash_attention", "value": f"{us:.0f}",
                 "derived": f"max_err={err:.2e} S={S}"})
    emit("kernels", rows, t0=t0)


if __name__ == "__main__":
    main()
