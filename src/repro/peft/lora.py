"""LoRA / QLoRA parameter-efficient fine-tuning (Hu et al. 2021; paper §II-A).

Adapters attach to 2-D weights whose leaf name is in ``cfg.lora.targets``
(attention + dense projections — matching the paper: "LoRA decomposes large
matrices into low-rank components within attention layers").  Expert tensors
(3-D) never get adapters; MoE fine-tuning goes through attention + shared
experts, which is the standard PEFT-on-MoE recipe.

QLoRA: base weights are blockwise int4-quantized (``quantize``/
``dequantize``); the Pallas kernel ``repro.kernels.int4_matmul`` consumes the
packed representation directly on TPU.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# adapter init / merge
# ---------------------------------------------------------------------------
def init_layer_adapters(key, cfg, layer_params: Dict) -> Dict:
    """Adapters for one (unstacked) layer param dict."""
    out = {}
    names = [n for n, p in sorted(layer_params.items())
             if n in cfg.lora.targets and getattr(p, "ndim", 0) == 2]
    packed = [n[:-3] for n, p in sorted(layer_params.items())
              if n.endswith("__q") and n[:-3] in cfg.lora.targets]
    names = sorted(set(names) | set(packed))
    if not names:
        return out
    keys = jax.random.split(key, len(names))
    for k, n in zip(keys, names):
        if n in layer_params:
            d_in, d_out = layer_params[n].shape
        else:                         # QLoRA-packed: out dim halved
            d_in, half = layer_params[f"{n}__q"].shape
            d_out = half * 2
        r = cfg.lora.rank
        out[f"{n}_lora_a"] = (jax.random.normal(k, (d_in, r), jnp.float32)
                              / jnp.sqrt(d_in))
        out[f"{n}_lora_b"] = jnp.zeros((r, d_out), jnp.float32)
    return out


def merge_layer(cfg, layer_params: Dict, adapters: Dict) -> Dict:
    """Fold adapters into base weights (inference deployment path)."""
    merged = dict(layer_params)
    scale = cfg.lora.alpha / cfg.lora.rank
    for n in list(layer_params):
        a = adapters.get(f"{n}_lora_a")
        if a is None:
            continue
        b = adapters[f"{n}_lora_b"]
        w = layer_params[n].astype(jnp.float32) + scale * (a @ b)
        merged[n] = w.astype(layer_params[n].dtype)
    return merged


def adapter_param_count(adapters) -> int:
    return sum(int(jnp.size(x)) for x in jax.tree.leaves(adapters))


# ---------------------------------------------------------------------------
# federated adapter algebra (FedAvg teacher + distillation blend)
# ---------------------------------------------------------------------------
def weighted_average_stacked(stacked, weights: jnp.ndarray):
    """FedAvg over a client-stacked adapter pytree.

    ``stacked`` holds ``(C, …)`` leaves (client axis leading); ``weights``
    is ``(C,)`` and is normalized here, so padding clients contribute
    nothing when their weight is 0.  Runs on device — under the
    ``'clients'`` mesh this is the one cross-client reduction of the LLM
    round program (GSPMD lowers it to a single all-reduce).
    """
    w = weights.astype(jnp.float32)
    w = w / jnp.sum(w)

    def leaf(x):
        wx = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)
        return jnp.sum(wx * x, axis=0)

    return jax.tree.map(leaf, stacked)


def blend_adapters(adapters, a_g, rho: float):
    """Distill toward the global teacher: a ← (1−ρ)·a + ρ·a_g.

    Works for one client's pytree or for a client-stacked pytree (a_g
    broadcasts along the leading client axis).
    """
    return jax.tree.map(
        lambda a, g: (1.0 - rho) * a + rho * g, adapters, a_g)


# ---------------------------------------------------------------------------
# QLoRA int4 blockwise quantization
# ---------------------------------------------------------------------------
QBLOCK = 64


def quantize(w: jnp.ndarray, block: int = QBLOCK
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise absmax int4.  w: (in, out) → packed (in, out//2) uint8 +
    scales (in, out//block) f32.  Values in [-7, 7]."""
    d_in, d_out = w.shape
    assert d_out % block == 0 and block % 2 == 0
    wb = w.astype(jnp.float32).reshape(d_in, d_out // block, block)
    scales = jnp.max(jnp.abs(wb), axis=-1, keepdims=True) / 7.0
    scales = jnp.maximum(scales, 1e-12)
    q = jnp.clip(jnp.round(wb / scales), -7, 7).astype(jnp.int8)
    q = q.reshape(d_in, d_out)
    lo = (q[:, 0::2] + 8).astype(jnp.uint8)
    hi = (q[:, 1::2] + 8).astype(jnp.uint8)
    packed = lo | (hi << 4)
    return packed, scales[..., 0]


def dequantize(packed: jnp.ndarray, scales: jnp.ndarray,
               block: int = QBLOCK, dtype=jnp.bfloat16) -> jnp.ndarray:
    d_in, half = packed.shape
    d_out = half * 2
    lo = (packed & 0xF).astype(jnp.int32) - 8
    hi = (packed >> 4).astype(jnp.int32) - 8
    q = jnp.stack([lo, hi], axis=-1).reshape(d_in, d_out).astype(jnp.float32)
    w = (q.reshape(d_in, d_out // block, block)
         * scales[..., None]).reshape(d_in, d_out)
    return w.astype(dtype)


def quantize_layer_flat(layer: dict, targets, block: int = QBLOCK) -> dict:
    """QLoRA a layer param dict IN FLAT FORM: each 2-D target weight ``w``
    is replaced by ``w__q`` (packed int4) + ``w__s`` (scales).  Flat names
    keep the sharding rules name-addressable (sharding._leaf_spec)."""
    out = {}
    for k, v in layer.items():
        if k in targets and getattr(v, "ndim", 0) == 2 \
                and v.shape[1] % block == 0:
            q, s = quantize(v, block)
            out[f"{k}__q"] = q
            out[f"{k}__s"] = s
        else:
            out[k] = v
    return out


def quantize_stacked_groups(params: dict, targets,
                            block: int = QBLOCK) -> dict:
    """Apply quantize_layer_flat across the stacked group structure
    (params['groups'] / ['enc_groups']: tuples of dicts of (G, ...) arrays)
    — vmapped so the leading group axis is preserved."""
    def one_stack(stack):
        return jax.vmap(
            lambda lyr: quantize_layer_flat(lyr, targets, block))(stack)

    out = dict(params)
    for gk in ("groups", "enc_groups"):
        if gk in params:
            out[gk] = tuple(one_stack(g) for g in params[gk])
    return out


def quantize_tree(params, targets, block: int = QBLOCK):
    """Quantize all matching 2-D leaves; returns (qtree, meta) where qtree
    stores {'q': packed, 's': scales} in place of the weight."""
    def walk(tree):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                if isinstance(v, (dict, tuple, list)):
                    out[k] = walk(v)
                elif k in targets and v.ndim == 2:
                    q, s = quantize(v, block)
                    out[k] = {"q": q, "s": s}
                else:
                    out[k] = v
            return out
        if isinstance(tree, (tuple, list)):
            return type(tree)(walk(v) for v in tree)
        return tree
    return walk(params)
