"""Device-resident masked batched Nelder–Mead: C simplexes, one program.

``gradfree.nm_run`` — the paper's default regulated optimizer — advances
one simplex with 1–4 lazy host evaluations per iteration, which makes it
the slowest possible citizen of the batched round engine: every eval is a
host↔device sync and the branch structure defeats batching.  The key
observation (ROADMAP "Batched Nelder–Mead") is that *every candidate
point of one simplex iteration depends only on the current simplex*:
reflect, expand, contract, and the ``n`` shrink points can all be
evaluated **speculatively** as one dense ``(C, n+3, P)`` batch through the
vmapped tape objective, and the branch the sequential method would have
taken is then selected per client with masked ``jnp.where`` logic.  The
loop body is branch-free, so ``lax.fori_loop`` compiles once and the
regulated per-client ``maxiter`` budgets arrive as a traced ``(C,)``
iteration mask exactly as in ``batched_spsa``.

Speculative evaluation spends ``n+3`` objective calls per iteration where
the sequential path spends 1–4 — wasted FLOPs, but they run as one fused
device batch, so wall-time per iteration is that of a *single* eval.
Communication-time accounting must not see the speculation: per-iteration
eval counts are accumulated on device from the branch actually taken
(expand 2, reflect 1, contract 2, shrink 2+n) so ``n_evals`` matches the
sequential ``nm_run`` eval-for-eval.

Branch decisions per iteration are recorded in a ``(C, max_iter)`` code
array (``BRANCH_*`` below; ``BRANCH_INACTIVE`` past a client's budget) —
the parity contract with ``gradfree.nm_run(..., trace=...)`` is decision-
for-decision equality, which ``tests/test_batched_nm.py`` enforces.

Finite-shot objectives (``keyed=True``) are called as ``f(xs, slot)``
with the slot schedule of the ``backends.py`` key-derivation contract:
init row ``r`` → slot ``r``; iteration ``i``'s speculative candidates
``[xr, xe, xc, shrink 1..n]`` → ``base..base+n+2`` with
``base = (n+1) + i·(n+3)``.  A candidate owns its slot whether it is
evaluated speculatively (here) or lazily (``gradfree.nm_run``), so the
draws of every candidate the sequential path *does* evaluate match
bitwise and the branch ladder decides identically.

Sharding safety: this optimizer is what runs under the engine's
``'clients'`` mesh axis, so two invariants are load-bearing (see
``core/batched_engine.py``):  every op in ``body`` must stay
**per-client independent** — elementwise or batched along ``C``, no
reduction/gather/permute across the client axis (``argsort`` and
``take_along_axis`` act on axis 1, within one client's simplex; the
scalar ``max(iters)`` loop bound is the single pre-loop exception) —
and the keyed slot schedule must stay a pure function of the
evaluation's **structural position**, never of client order or shard
placement.  Break either and the sharded round stops being bitwise
the single-device round.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# branch codes, aligned with gradfree.nm_run(trace=...)
BRANCH_EXPAND_XE = 0      # fr < f_best, fe < fr  → worst ← xe   (2 evals)
BRANCH_EXPAND_XR = 1      # fr < f_best, fe ≥ fr  → worst ← xr   (2 evals)
BRANCH_REFLECT = 2        # f_best ≤ fr < f_2nd   → worst ← xr   (1 eval)
BRANCH_CONTRACT = 3       # fc < f_worst          → worst ← xc   (2 evals)
BRANCH_SHRINK = 4         # rows 1..n shrink toward best      (2+n evals)
BRANCH_INACTIVE = -1      # iteration ≥ the client's regulated budget


def init_simplexes(x0: jnp.ndarray, *, step: float = 0.25) -> jnp.ndarray:
    """(C, P) starts → (C, P+1, P) simplex stacks, the ``nm_init`` rule:
    row i+1 offsets coordinate i by ``step`` (or ``step·|x|+step``)."""
    x0 = jnp.asarray(x0, jnp.float32)
    n = x0.shape[-1]
    offset = jnp.where(x0 == 0, step, step * jnp.abs(x0) + step)  # (C, P)
    basis = jnp.eye(n + 1, n, k=-1, dtype=x0.dtype)               # (n+1, n)
    return x0[:, None, :] + basis[None] * offset[:, None, :]


def batched_nm(f: Callable, x0: jnp.ndarray, iters: jnp.ndarray,
               max_iter: int, *,
               alpha=1.0, gamma=2.0, rho=0.5, sigma=0.5, step: float = 0.25,
               keyed: bool = False, active: jnp.ndarray = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Masked batched Nelder–Mead.  Traceable (use under ``jax.jit``).

    f        : (C, P) → (C,)  vmapped objective; with ``keyed=True`` it
               is called as ``f(xs, slot)`` where ``slot`` is the
               (traced) contract slot (see module docstring)
    x0       : (C, P) start (typically θ_g broadcast to all clients)
    iters    : (C,)   per-client iteration budgets (mask, not trip count)
    max_iter : static upper bound on any budget (branch-record width)
    active   : optional (C,) bool participation mask (see
               ``batched_spsa``): an inactive client's budget is forced
               to 0 — its simplex stays the untouched init simplex, its
               branch row stays ``BRANCH_INACTIVE`` — and both its init
               and per-iteration eval counts are 0.  ``None`` is bitwise
               the all-active behavior.

    Returns ``(simplex (C, n+1, P), fvals (C, n+1), n_evals (C,),
    branches (C, max_iter) int32)``.  ``n_evals`` counts what the
    sequential path spends: ``n+1`` init plus the taken branch's evals per
    iteration.  The best point is ``simplex[c, argmin(fvals[c])]``.
    """
    x0 = jnp.asarray(x0, jnp.float32)
    iters = jnp.asarray(iters, jnp.int32)
    C, n = x0.shape
    if active is not None:
        active = jnp.asarray(active, bool)
        iters = jnp.where(active, iters, 0)

    # f over a (C, K, P) candidate stack (+ (K,) slots) → (C, K)
    if keyed:
        fstack = jax.vmap(f, in_axes=(1, 0), out_axes=1)
    else:
        fstack = lambda cand, slots: jax.vmap(
            lambda xs: f(xs), in_axes=1, out_axes=1)(cand)

    simplex0 = init_simplexes(x0, step=step)
    fvals0 = fstack(simplex0, jnp.arange(n + 1))             # (C, n+1)
    evals0 = jnp.full((C,), n + 1, jnp.int32)
    if active is not None:
        evals0 = jnp.where(active, evals0, 0)
    branches0 = jnp.full((C, int(max_iter)), BRANCH_INACTIVE, jnp.int32)

    def body(i, carry):
        simplex, fvals, evals, branches = carry
        order = jnp.argsort(fvals, axis=1)                   # stable
        sx = jnp.take_along_axis(simplex, order[:, :, None], axis=1)
        sf = jnp.take_along_axis(fvals, order, axis=1)
        best, worst = sx[:, 0, :], sx[:, -1, :]
        f_best, f_2nd, f_worst = sf[:, 0], sf[:, -2], sf[:, -1]
        centroid = jnp.mean(sx[:, :-1, :], axis=1)           # (C, P)

        xr = centroid + alpha * (centroid - worst)
        xe = centroid + gamma * (xr - centroid)
        xc = centroid + rho * (worst - centroid)
        shrink_x = best[:, None, :] + sigma * (sx[:, 1:, :] - best[:, None, :])
        cand = jnp.concatenate(
            [jnp.stack([xr, xe, xc], axis=1), shrink_x], axis=1)
        slots = (n + 1) + i * (n + 3) + jnp.arange(n + 3)
        fcand = fstack(cand, slots)                          # (C, n+3)
        fr, fe, fc = fcand[:, 0], fcand[:, 1], fcand[:, 2]
        f_shrink = fcand[:, 3:]

        # the sequential branch ladder, as per-client masks
        expand = fr < f_best
        take_xe = expand & (fe < fr)
        reflect = ~expand & (fr < f_2nd)
        contract = ~expand & ~reflect & (fc < f_worst)
        shrink = ~expand & ~reflect & ~contract

        use_xr = (expand & ~take_xe) | reflect
        new_worst_x = jnp.where(take_xe[:, None], xe,
                                jnp.where(use_xr[:, None], xr, xc))
        new_worst_f = jnp.where(take_xe, fe, jnp.where(use_xr, fr, fc))
        repl_x = sx.at[:, -1, :].set(new_worst_x)
        repl_f = sf.at[:, -1].set(new_worst_f)
        shr_x = jnp.concatenate([sx[:, :1, :], shrink_x], axis=1)
        shr_f = jnp.concatenate([sf[:, :1], f_shrink], axis=1)
        upd_x = jnp.where(shrink[:, None, None], shr_x, repl_x)
        upd_f = jnp.where(shrink[:, None], shr_f, repl_f)

        active = i < iters
        simplex = jnp.where(active[:, None, None], upd_x, simplex)
        fvals = jnp.where(active[:, None], upd_f, fvals)
        spent = jnp.where(reflect, 1,
                          jnp.where(shrink, 2 + n, 2)).astype(jnp.int32)
        evals = evals + jnp.where(active, spent, 0)
        code = jnp.where(
            take_xe, BRANCH_EXPAND_XE,
            jnp.where(expand, BRANCH_EXPAND_XR,
                      jnp.where(reflect, BRANCH_REFLECT,
                                jnp.where(contract, BRANCH_CONTRACT,
                                          BRANCH_SHRINK)))).astype(jnp.int32)
        branches = jax.lax.dynamic_update_slice(
            branches, jnp.where(active, code, BRANCH_INACTIVE)[:, None],
            (0, i))
        return simplex, fvals, evals, branches

    n_steps = jnp.minimum(jnp.max(iters), max_iter)
    out = jax.lax.fori_loop(0, n_steps, body,
                            (simplex0, fvals0, evals0, branches0))
    return out


def best_point(simplex: jnp.ndarray, fvals: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-client incumbent: (x (C, P), f (C,)) at ``argmin(fvals)``."""
    idx = jnp.argmin(fvals, axis=1)
    x = jnp.take_along_axis(simplex, idx[:, None, None], axis=1)[:, 0, :]
    return x, jnp.take_along_axis(fvals, idx[:, None], axis=1)[:, 0]
