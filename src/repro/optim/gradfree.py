"""Gradient-free optimizers with COBYLA-compatible ``maxiter`` semantics.

The paper drives its quantum models with Qiskit's COBYLA and regulates a
single knob — ``maxiter`` (function-evaluation budget per local round).
COBYLA internals are irrelevant to the contribution (DESIGN.md §6.2); what
matters is a black-box minimizer whose progress is metered in iterations.
We provide:

 - ``NelderMead`` : simplex method (default; deterministic, robust on the
   ≤30-parameter VQC/QCNN landscapes).  One "iteration" = one simplex
   transformation (1–4 function evals), matching scipy/COBYLA's notion of
   a metered step.
 - ``SPSA``       : simultaneous-perturbation stochastic approximation
   (2 evals/iteration), the standard QML alternative.

Both are **resumable**: state in/out, so the federated loop can run
``k`` iterations this round, have the controller re-regulate ``maxiter``,
and continue from the same optimizer state next round — exactly the
paper's regulated-optimizer execution model (Alg. 1 lines 11–17).

Finite-shot objectives take a ``key_stream``: a callable mapping the
evaluation's structural **slot** (the ``backends.py`` key-derivation
contract — init rows, then per-iteration candidate positions) to a PRNG
key, in which case the objective is called as ``fn(x, key)``.  Slots are
derived from the *global* iteration counter (``NMState.n_iters`` /
``SPSAState.k``), so resumed runs keep drawing from fresh slots, and the
batched optimizers (``batched_spsa`` / ``batched_nm``) use the identical
schedule — draw-for-draw parity on noisy backends.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.quantum.backends import FINAL_EVAL_SLOT


def _call(fn: Callable, x, key_stream, slot: int) -> float:
    """One objective evaluation at its contract slot (keyed or not)."""
    if key_stream is None:
        return float(fn(x))
    return float(fn(x, key_stream(slot)))


# ---------------------------------------------------------------------------
# Nelder–Mead
# ---------------------------------------------------------------------------
@dataclass
class NMState:
    simplex: np.ndarray          # (n+1, n)
    fvals: np.ndarray            # (n+1,)
    n_evals: int = 0
    n_iters: int = 0

    @property
    def best_x(self) -> np.ndarray:
        return self.simplex[int(np.argmin(self.fvals))]

    @property
    def best_f(self) -> float:
        return float(np.min(self.fvals))


def nm_init(fn: Callable, x0: np.ndarray, *, step: float = 0.25,
            key_stream=None) -> NMState:
    x0 = np.asarray(x0, np.float64)
    n = x0.shape[0]
    simplex = np.tile(x0, (n + 1, 1))
    for i in range(n):
        simplex[i + 1, i] += step if x0[i] == 0 else step * abs(x0[i]) + step
    # contract slots 0..n: one per initial simplex row
    fvals = np.array([_call(fn, s, key_stream, r)
                      for r, s in enumerate(simplex)])
    return NMState(simplex, fvals, n_evals=n + 1)


def nm_run(fn: Callable, state: NMState, maxiter: int,
           *, alpha=1.0, gamma=2.0, rho=0.5, sigma=0.5,
           trace: Optional[List[int]] = None, key_stream=None) -> NMState:
    """Run ``maxiter`` simplex iterations from ``state`` (resumable).

    ``trace``, if given, receives one ``batched_nm.BRANCH_*`` code per
    iteration — the decision-parity contract with the batched engine.
    """
    simplex = state.simplex.copy()
    fvals = state.fvals.copy()
    n = simplex.shape[1]
    evals = 0

    for it in range(max(0, int(maxiter))):
        # contract slots for global iteration i: base + {0: reflect,
        # 1: expand, 2: contract, 2+j: shrink row j}
        base = (n + 1) + (state.n_iters + it) * (n + 3)
        # stable sort: ties resolve identically to the batched engine
        order = np.argsort(fvals, kind="stable")
        simplex, fvals = simplex[order], fvals[order]
        centroid = simplex[:-1].mean(axis=0)
        branch = -1

        xr = centroid + alpha * (centroid - simplex[-1])
        fr = _call(fn, xr, key_stream, base); evals += 1
        if fr < fvals[0]:
            xe = centroid + gamma * (xr - centroid)
            fe = _call(fn, xe, key_stream, base + 1); evals += 1
            if fe < fr:
                simplex[-1], fvals[-1] = xe, fe
                branch = 0                      # BRANCH_EXPAND_XE
            else:
                simplex[-1], fvals[-1] = xr, fr
                branch = 1                      # BRANCH_EXPAND_XR
        elif fr < fvals[-2]:
            simplex[-1], fvals[-1] = xr, fr
            branch = 2                          # BRANCH_REFLECT
        else:
            xc = centroid + rho * (simplex[-1] - centroid)
            fc = _call(fn, xc, key_stream, base + 2); evals += 1
            if fc < fvals[-1]:
                simplex[-1], fvals[-1] = xc, fc
                branch = 3                      # BRANCH_CONTRACT
            else:   # shrink
                branch = 4                      # BRANCH_SHRINK
                for i in range(1, n + 1):
                    simplex[i] = simplex[0] + sigma * (simplex[i] - simplex[0])
                    fvals[i] = _call(fn, simplex[i], key_stream, base + 2 + i)
                    evals += 1
        if trace is not None:
            trace.append(branch)

    return NMState(simplex, fvals, state.n_evals + evals,
                   state.n_iters + max(0, int(maxiter)))


# ---------------------------------------------------------------------------
# SPSA
# ---------------------------------------------------------------------------
def spsa_rng(seed: int, k: int) -> np.random.Generator:
    """Rademacher stream for a resumed SPSA run.

    ``default_rng(seed + k)`` would collide across clients: federated
    client seeds are consecutive (``rc.seed·997 + i``), so client ``i``
    resumed at iteration ``k`` would replay client ``i+k``'s fresh stream.
    ``SeedSequence((seed, k))`` hashes the pair, keeping every
    (client, resume-point) stream distinct.  ``batched_spsa.make_deltas``
    derives its draws from this same function — draw-for-draw parity.
    """
    return np.random.default_rng(np.random.SeedSequence((int(seed), int(k))))


@dataclass
class SPSAState:
    x: np.ndarray
    f: float
    k: int = 0                  # global iteration counter (gain schedule)
    n_evals: int = 0
    seed: int = 0

    @property
    def best_x(self) -> np.ndarray:
        return self.x

    @property
    def best_f(self) -> float:
        return float(self.f)


def spsa_init(fn: Callable, x0: np.ndarray, *, seed: int = 0,
              key_stream=None) -> SPSAState:
    x0 = np.asarray(x0, np.float64)
    return SPSAState(x0, _call(fn, x0, key_stream, 0), n_evals=1, seed=seed)


def spsa_run(fn: Callable, state: SPSAState, maxiter: int, *,
             a=0.2, c=0.15, A=10.0, alpha=0.602, gamma=0.101,
             clip: float = 1.0, key_stream=None) -> SPSAState:
    rng = spsa_rng(state.seed, state.k)
    x, fbest, k, evals = state.x.copy(), state.f, state.k, 0
    for _ in range(max(0, int(maxiter))):
        ak = a / (k + 1 + A) ** alpha
        ck = c / (k + 1) ** gamma
        delta = rng.choice([-1.0, 1.0], size=x.shape)
        # contract slots for global iteration k: 1+3k, 2+3k, 3+3k
        fp = _call(fn, x + ck * delta, key_stream, 1 + 3 * k)
        fm = _call(fn, x - ck * delta, key_stream, 2 + 3 * k)
        evals += 2
        ghat = (fp - fm) / (2 * ck) * (1.0 / delta)
        gn = float(np.linalg.norm(ghat))
        if clip and gn > clip:          # norm-clip: stabilizes rough
            ghat = ghat * (clip / gn)   # quantum loss landscapes
        cand = x - ak * ghat
        fc = _call(fn, cand, key_stream, 3 + 3 * k); evals += 1
        if fc <= fbest + abs(fbest) * 0.1 + 1e-3:   # blocking step
            x, fbest = cand, min(fbest, fc)
        k += 1
    return SPSAState(x, _call(fn, x, key_stream, FINAL_EVAL_SLOT), k,
                     state.n_evals + evals + 1, state.seed)


# ---------------------------------------------------------------------------
# unified resumable facade (what core/ uses)
# ---------------------------------------------------------------------------
class GradFreeOptimizer:
    """Resumable metered optimizer.  ``run(maxiter)`` advances the state;
    the controller owns the budget (the paper's regulation law)."""

    def __init__(self, fn: Callable, x0, *, method: str = "nelder-mead",
                 seed: int = 0, key_stream=None):
        self.fn = fn
        self.method = method
        self.key_stream = key_stream
        if method == "nelder-mead":
            self.state = nm_init(fn, np.asarray(x0), key_stream=key_stream)
        elif method == "spsa":
            self.state = spsa_init(fn, np.asarray(x0), seed=seed,
                                   key_stream=key_stream)
        else:
            raise ValueError(method)

    def run(self, maxiter: int) -> Tuple[np.ndarray, float]:
        if self.method == "nelder-mead":
            self.state = nm_run(self.fn, self.state, maxiter,
                                key_stream=self.key_stream)
        else:
            self.state = spsa_run(self.fn, self.state, maxiter,
                                  key_stream=self.key_stream)
        return self.state.best_x, self.state.best_f

    def set_fn(self, fn: Callable):
        """Swap the objective (e.g. distillation weight changed) without
        resetting optimizer geometry.  Keyed objectives re-evaluate on
        the init slots (rows 0..n / slot 0) — a deliberate replay."""
        self.fn = fn
        ks = self.key_stream
        if self.method == "nelder-mead":
            st = self.state
            fvals = np.array([_call(fn, s, ks, r)
                              for r, s in enumerate(st.simplex)])
            self.state = NMState(st.simplex, fvals, st.n_evals + len(fvals),
                                 st.n_iters)
        else:
            st = self.state
            self.state = replace(st, f=_call(fn, st.x, ks, 0),
                                 n_evals=st.n_evals + 1)

    @property
    def n_evals(self) -> int:
        return self.state.n_evals

    @property
    def best(self) -> Tuple[np.ndarray, float]:
        return self.state.best_x, self.state.best_f
