"""Device-resident batched SPSA: all clients, all iterations, one program.

``gradfree.spsa_run`` minimizes one objective with a host↔device roundtrip
per evaluation (``float(fn(x))``) — ~3 syncs per iteration per client, the
dominant cost of a federated round on the simulator.  This module runs the
same update law for **C clients simultaneously** inside ``lax.fori_loop``:
parameters live on device as a ``(C, P)`` stack, the objective is the
vmapped per-client loss ``f : (C, P) → (C,)``, and nothing touches the
host until the loop returns.

Per-client ``maxiter`` budgets (the paper's regulated knob) are honored
via **iteration masks**: the loop runs to ``max(iters)`` (a traced bound —
no recompilation when regulation changes budgets) and client ``c`` simply
stops updating once ``i >= iters[c]``.  Masked iterations still evaluate
``f`` for the full stack — wasted FLOPs, zero wasted wall-time relative to
the sequential path, and bitwise-inert for the masked clients.

Parity with the sequential reference is bit-for-bit in the *random draws*:
perturbation signs are precomputed on host by ``make_deltas`` with the
exact ``np.random.default_rng(seed)`` call sequence of
``gradfree.spsa_run``, so a batched round sees the same Rademacher
directions as C sequential runs with seeds ``seeds[c]``.

Finite-shot objectives (``keyed=True``) are called as ``f(xs, slot)``
with the slot schedule of the ``backends.py`` key-derivation contract —
init → 0, iteration ``k`` → ``1+3k`` / ``2+3k`` / ``3+3k``, final polish
→ ``FINAL_EVAL_SLOT`` — exactly the slots ``gradfree.spsa_run`` hands
its ``key_stream``, so shot-count draws match the sequential path
draw-for-draw.
"""
from __future__ import annotations

from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.quantum.backends import FINAL_EVAL_SLOT


def make_deltas(seeds: Sequence[int], max_iter: int, dim: int) -> np.ndarray:
    """(C, max_iter, dim) Rademacher directions, matching the draw order of
    ``gradfree.spsa_run`` (one ``rng.choice([-1,1], size=dim)`` per iter,
    the ``gradfree.spsa_rng(seed, 0)`` stream per client — a fresh run)."""
    from repro.optim.gradfree import spsa_rng
    out = np.empty((len(seeds), max_iter, dim), np.float64)
    for c, seed in enumerate(seeds):
        rng = spsa_rng(seed, 0)
        for i in range(max_iter):
            out[c, i] = rng.choice([-1.0, 1.0], size=dim)
    return out


def batched_spsa(f: Callable, x0: jnp.ndarray, iters: jnp.ndarray,
                 deltas: jnp.ndarray, *,
                 a=0.2, c=0.15, A=10.0, alpha=0.602, gamma=0.101,
                 clip: float = 1.0, keyed: bool = False,
                 active: jnp.ndarray = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Masked batched SPSA.  Traceable (use under ``jax.jit``).

    f      : (C, P) → (C,)  vmapped objective; with ``keyed=True`` it is
             called as ``f(xs, slot)`` where ``slot`` is the (traced)
             contract slot of the evaluation (see module docstring)
    x0     : (C, P) start (typically θ_g broadcast to all clients)
    iters  : (C,)   per-client iteration budgets (mask, not trip count)
    deltas : (C, M, P) precomputed perturbation signs, M ≥ max(iters)
    active : optional (C,) bool — the fused driver's participation mask
             (dropped / straggler / outside-cohort clients): an inactive
             client's budget is forced to 0 (it never updates, so ``x``
             returns its start row) and its ``n_evals`` is 0, because a
             client that never participated spends nothing.  ``None``
             (every call outside the fused driver) is bitwise the
             all-active behavior.

    Returns (x (C,P), f_final (C,), n_evals (C,)) where ``n_evals`` counts
    what the sequential path would have spent: 1 init + 3/iter + 1 final.
    """
    x0 = jnp.asarray(x0, jnp.float32)
    iters = jnp.asarray(iters, jnp.int32)
    deltas = jnp.asarray(deltas, jnp.float32)
    if active is not None:
        active = jnp.asarray(active, bool)
        iters = jnp.where(active, iters, 0)

    if keyed:
        call = f
        pair = jax.vmap(f)                       # (2,C,P),(2,) → (2,C)
    else:
        call = lambda xs, slot: f(xs)
        pair = jax.vmap(lambda xs, slot: f(xs))
    f0 = call(x0, jnp.int32(0))

    def body(i, carry):
        x, fbest = carry
        ak = a / (i + 1.0 + A) ** alpha
        ck = c / (i + 1.0) ** gamma
        d = deltas[:, i, :]                              # (C, P)
        base = 1 + 3 * i
        fpm = pair(jnp.stack([x + ck * d, x - ck * d]),
                   jnp.stack([base, base + 1]))
        ghat = (fpm[0] - fpm[1])[:, None] / (2.0 * ck) * (1.0 / d)
        gn = jnp.linalg.norm(ghat, axis=-1, keepdims=True)
        if clip:
            ghat = jnp.where(gn > clip, ghat * (clip / gn), ghat)
        cand = x - ak * ghat
        fc = call(cand, base + 2)
        accept = fc <= fbest + jnp.abs(fbest) * 0.1 + 1e-3  # blocking step
        upd = accept & (i < iters)
        x = jnp.where(upd[:, None], cand, x)
        fbest = jnp.where(upd, jnp.minimum(fbest, fc), fbest)
        return x, fbest

    n_steps = jnp.max(iters)
    x, _ = jax.lax.fori_loop(0, n_steps, body, (x0, f0))
    n_evals = 2 + 3 * iters
    if active is not None:
        n_evals = jnp.where(active, n_evals, 0)
    return x, call(x, jnp.int32(FINAL_EVAL_SLOT)), n_evals
