"""Minimal AdamW for adapter (LoRA) training — pytree-native, jit-safe."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def init(params) -> AdamWState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=z,
                      nu=jax.tree.map(jnp.copy, z))


def update(grads, state: AdamWState, params, *, lr=1e-4, b1=0.9, b2=0.999,
           eps=1e-8, weight_decay=0.0):
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        upd = (m / c1) / (jnp.sqrt(v / c2) + eps)
        if weight_decay:
            upd = upd + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
