"""Flash attention Pallas kernel (online softmax, optional sliding window).

Canonical TPU tiling: grid (B·H, S_q/bq, S_k/bk) with the KV axis
innermost; running max / sum / accumulator live in VMEM scratch and
persist across the KV grid steps (revisiting semantics).  The (bq, bk)
logits tile exists only in VMEM — attention memory is O(S·D), not O(S²).

Sliding window (starcoder2, long-decode variants): blocks entirely outside
[qpos−window+1, qpos] are masked; with block-aligned windows the mask is a
cheap iota comparison (no gather).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            n_k: int):
    j = pl.program_id(2)
    i = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                    # (bq, D)
    k = k_ref[0].astype(jnp.float32)                    # (bk, D)
    v = v_ref[0].astype(jnp.float32)                    # (bk, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                 # (bq,)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_cur[:, None])
    # rows with no valid key yet: keep everything zeroed
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jnp.dot(p, v, preferred_element_type=jnp.float32))
    m_ref[...] = m_cur

    @pl.when(j == n_k - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float = None, bq: int = 128, bk: int = 128,
                    interpret: bool = True):
    """q (B,H,S,D), k/v (B,H,S_k,D) already GQA-expanded → (B,H,S,D)."""
    B, H, S, D = q.shape
    Sk = k.shape[2]
    scale = scale or (D ** -0.5)
    bq, bk = min(bq, S), min(bk, Sk)
    assert S % bq == 0 and Sk % bk == 0
    qr = q.reshape(B * H, S, D)
    kr = k.reshape(B * H, Sk, D)
    vr = v.reshape(B * H, Sk, D)
    n_k = Sk // bk
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, n_k=n_k),
        grid=(B * H, S // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running sum
            pltpu.VMEM((bq, D), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, S, D)
