"""Fused LoRA matmul Pallas kernel:  y = x@W + s·(x@A)@B.

TPU adaptation (DESIGN.md §5): the rank-r bottleneck (x@A, (bm, r)) is
computed in VMEM and consumed immediately by the B-projection — the
low-rank intermediate never round-trips HBM, and both matmuls feed the
MXU with 128-aligned tiles.

Grid: (M/bm, N/bn).  Per step the kernel sees
    x     (bm, K)   — full reduction dim in VMEM
    w     (K, bn)
    a     (K, r)    — broadcast over the N grid axis
    b     (r, bn)
VMEM at defaults (bm=bn=128, K≤8192, bf16): ~4.3 MiB — fits v5e's 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref, *, scale: float):
    x = x_ref[...].astype(jnp.float32)
    acc = jnp.dot(x, w_ref[...].astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    xa = jnp.dot(x, a_ref[...].astype(jnp.float32),
                 preferred_element_type=jnp.float32)        # (bm, r)
    acc = acc + scale * jnp.dot(xa, b_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("scale", "bm", "bn", "interpret"))
def lora_matmul(x, w, a, b, *, scale: float, bm: int = 128, bn: int = 128,
                interpret: bool = True):
    """x (M,K) @ w (K,N) + scale·(x@a (K,r))@b (r,N) → (M,N)."""
    M, K = x.shape
    _, N = w.shape
    r = a.shape[1]
    bm, bn = min(bm, M), min(bn, N)
    while M % bm:
        bm //= 2
    while N % bn:
        bn //= 2
    assert M % bm == 0 and N % bn == 0, (M, N, bm, bn)
    grid = (M // bm, N // bn)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bn), lambda i, j: (0, j)),
            pl.BlockSpec((K, r), lambda i, j: (0, 0)),
            pl.BlockSpec((r, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(x, w, a, b)
