"""Public jit'd wrappers over the Pallas kernels.

On this CPU container every kernel executes with ``interpret=True``
(Pallas interpreter — bit-accurate kernel-body semantics); on TPU the same
call sites pass ``interpret=False`` and compile to Mosaic.  ``INTERPRET``
flips the default globally.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import distill_kl as _kl
from repro.kernels import flash_attention as _fa
from repro.kernels import int4_matmul as _i4
from repro.kernels import lora_matmul as _lm
from repro.kernels import statevector_gates as _svg

INTERPRET = jax.default_backend() == "cpu"


def lora_matmul(x, w, a, b, *, scale: float, **kw):
    kw.setdefault("interpret", INTERPRET)
    return _lm.lora_matmul(x, w, a, b, scale=scale, **kw)


def int4_matmul(x, packed, scales, *, qblock: int = 64, **kw):
    kw.setdefault("interpret", INTERPRET)
    return _i4.int4_matmul(x, packed, scales, qblock=qblock, **kw)


def distill_kl(teacher_probs, student_logits, **kw):
    kw.setdefault("interpret", INTERPRET)
    return _kl.distill_kl(teacher_probs, student_logits, **kw)


def distill_kl_mean(teacher_probs, student_logits, **kw):
    return jnp.mean(distill_kl(teacher_probs, student_logits, **kw))


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0, **kw):
    kw.setdefault("interpret", INTERPRET)
    return _fa.flash_attention(q, k, v, causal=causal, window=window, **kw)


def statevector_gate(psi_re, psi_im, g_re, g_im, idx0, idx1, cmask, **kw):
    # interpret-only for now: the kernel body's dynamic gather/scatter on
    # idx0/idx1 does not lower through Mosaic yet (ROADMAP open item)
    kw.setdefault("interpret", True)
    return _svg.statevector_gate(psi_re, psi_im, g_re, g_im,
                                 idx0, idx1, cmask, **kw)
