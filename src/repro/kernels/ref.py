"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematical definition the kernels must match under
``assert_allclose`` across shape/dtype sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lora_matmul(x, w, a, b, scale: float):
    """y = x @ W + scale · (x @ A) @ B   (f32 accumulation)."""
    xf = x.astype(jnp.float32)
    y = xf @ w.astype(jnp.float32)
    y = y + scale * ((xf @ a.astype(jnp.float32)) @ b.astype(jnp.float32))
    return y.astype(x.dtype)


def int4_matmul(x, packed, scales, block: int):
    """y = x @ dequant(packed, scales)  — QLoRA base-weight path."""
    from repro.peft.lora import dequantize
    w = dequantize(packed, scales, block, dtype=jnp.float32)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def distill_kl(teacher_probs, student_logits, eps: float = 1e-9):
    """Per-row KL(P_t ‖ softmax(z)) — fused softmax+KL contract.  (B,)"""
    pt = jnp.clip(teacher_probs.astype(jnp.float32), eps, 1.0)
    logq = jax.nn.log_softmax(student_logits.astype(jnp.float32), axis=-1)
    return jnp.sum(pt * (jnp.log(pt) - logq), axis=-1)


def statevector_gate(psi_re, psi_im, g_re, g_im, idx0, idx1, cmask):
    """Batched controlled 2×2 gate on split-plane statevectors.

    psi: (B, N) re/im planes; g: (B, 2, 2) re/im planes; idx0/idx1:
    (N/2,) flat indices of the target-bit 0/1 amplitude pairs; cmask:
    (N/2,) 1.0 where the gate acts.  Returns the new (re, im) planes.
    """
    a0 = psi_re[:, idx0].astype(jnp.float32) \
        + 1j * psi_im[:, idx0].astype(jnp.float32)
    a1 = psi_re[:, idx1].astype(jnp.float32) \
        + 1j * psi_im[:, idx1].astype(jnp.float32)
    g = g_re.astype(jnp.float32) + 1j * g_im.astype(jnp.float32)
    n0 = g[:, 0, 0, None] * a0 + g[:, 0, 1, None] * a1
    n1 = g[:, 1, 0, None] * a0 + g[:, 1, 1, None] * a1
    m = cmask[None, :]
    n0 = m * n0 + (1.0 - m) * a0
    n1 = m * n1 + (1.0 - m) * a1
    out_re = psi_re.at[:, idx0].set(n0.real).at[:, idx1].set(n1.real)
    out_im = psi_im.at[:, idx0].set(n0.imag).at[:, idx1].set(n1.imag)
    return out_re, out_im


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float = None):
    """Reference attention (B, H, S, D) with GQA-expanded k/v and optional
    sliding window (k attendable iff 0 ≤ qpos−kpos < window)."""
    B, H, S, D = q.shape
    scale = scale or (D ** -0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((S, k.shape[2]), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
