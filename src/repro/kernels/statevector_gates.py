"""Pallas kernel: batched (controlled) 2×2 gate apply on flat statevectors.

The circuit-tape executor (``repro.quantum.tape``) reduces every gate of
the paper's circuits to one controlled 2×2 unitary acting on index pairs
of a ``(B, 2**n)`` statevector batch.  This kernel fuses the gather of
both amplitude planes, the complex 2×2 mat-vec, the control masking, and
the scatter back — one read and one write of the statevector per gate.

Complex amplitudes travel as separate real/imag float32 planes (TPU
Pallas has no complex dtype); the per-example gate matrices arrive as
``(B, 2, 2)`` re/im planes.  Pairing metadata is precomputed outside
(``tape.pair_indices``): ``idx0``/``idx1`` are the flat indices of the
target-bit-0/1 amplitudes and ``cmask`` is 1.0 where the gate acts
(control bit set, or uncontrolled).

Grid: (B/bb,).  Blocks: planes (bb, N), gates (bb, 2, 2), metadata
(N/2,) broadcast to every program.  Oracle: ``ref.statevector_gate``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(pr_ref, pi_ref, gr_ref, gi_ref, i0_ref, i1_ref, m_ref,
            or_ref, oi_ref):
    pr = pr_ref[...].astype(jnp.float32)
    pi = pi_ref[...].astype(jnp.float32)
    i0 = i0_ref[...]
    i1 = i1_ref[...]
    m = m_ref[...][None, :]

    a0r, a0i = pr[:, i0], pi[:, i0]
    a1r, a1i = pr[:, i1], pi[:, i1]

    gr = gr_ref[...].astype(jnp.float32)
    gi = gi_ref[...].astype(jnp.float32)
    g00r, g01r = gr[:, 0, 0, None], gr[:, 0, 1, None]
    g10r, g11r = gr[:, 1, 0, None], gr[:, 1, 1, None]
    g00i, g01i = gi[:, 0, 0, None], gi[:, 0, 1, None]
    g10i, g11i = gi[:, 1, 0, None], gi[:, 1, 1, None]

    n0r = g00r * a0r - g00i * a0i + g01r * a1r - g01i * a1i
    n0i = g00r * a0i + g00i * a0r + g01r * a1i + g01i * a1r
    n1r = g10r * a0r - g10i * a0i + g11r * a1r - g11i * a1i
    n1i = g10r * a0i + g10i * a0r + g11r * a1i + g11i * a1r

    n0r = m * n0r + (1.0 - m) * a0r
    n0i = m * n0i + (1.0 - m) * a0i
    n1r = m * n1r + (1.0 - m) * a1r
    n1i = m * n1i + (1.0 - m) * a1i

    or_ref[...] = pr.at[:, i0].set(n0r).at[:, i1].set(n1r)
    oi_ref[...] = pi.at[:, i0].set(n0i).at[:, i1].set(n1i)


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def statevector_gate(psi_re, psi_im, g_re, g_im, idx0, idx1, cmask, *,
                     bb: int = 256, interpret: bool = True):
    """(B,N)×2 planes, (B,2,2)×2 gate planes, (N/2,) pairing → new planes."""
    B, N = psi_re.shape
    bb = min(bb, B)
    while B % bb:
        bb //= 2
    assert B % bb == 0
    half = N // 2
    meta_spec = pl.BlockSpec((half,), lambda i: (0,))
    plane_spec = pl.BlockSpec((bb, N), lambda i: (i, 0))
    gate_spec = pl.BlockSpec((bb, 2, 2), lambda i: (i, 0, 0))
    out = pl.pallas_call(
        _kernel,
        grid=(B // bb,),
        in_specs=[plane_spec, plane_spec, gate_spec, gate_spec,
                  meta_spec, meta_spec, meta_spec],
        out_specs=[plane_spec, plane_spec],
        out_shape=[jax.ShapeDtypeStruct((B, N), jnp.float32),
                   jax.ShapeDtypeStruct((B, N), jnp.float32)],
        interpret=interpret,
    )(psi_re, psi_im, g_re, g_im, idx0, idx1, cmask)
    return out[0], out[1]
