"""Fused distillation-KL Pallas kernel: per-row KL(P_t ‖ softmax(z)).

Fuses the student softmax (max-shifted logsumexp) with the KL reduction so
the normalized student distribution never hits HBM — one read of (P_t, z),
one write of (B,) row KLs.

Grid: (B/bb,).  Blocks: teacher (bb, C), logits (bb, C), out (bb,).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(t_ref, z_ref, o_ref, *, eps: float):
    pt = jnp.clip(t_ref[...].astype(jnp.float32), eps, 1.0)
    z = z_ref[...].astype(jnp.float32)
    m = jnp.max(z, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(z - m), axis=-1, keepdims=True)) + m
    logq = z - lse
    o_ref[...] = jnp.sum(pt * (jnp.log(pt) - logq), axis=-1)


@functools.partial(jax.jit, static_argnames=("eps", "bb", "interpret"))
def distill_kl(teacher_probs, student_logits, *, eps: float = 1e-9,
               bb: int = 256, interpret: bool = True):
    """(B, C), (B, C) → per-row KL (B,) float32."""
    B, C = teacher_probs.shape
    bb = min(bb, B)
    while B % bb:
        bb //= 2
    assert B % bb == 0
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(B // bb,),
        in_specs=[pl.BlockSpec((bb, C), lambda i: (i, 0)),
                  pl.BlockSpec((bb, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        interpret=interpret,
    )(teacher_probs, student_logits)
