"""QLoRA int4 matmul Pallas kernel:  y = x @ dequant(packed, scales).

The packed base weight stays int4 in HBM (4× smaller than bf16) and is
dequantized **in VMEM** tile-by-tile right before the MXU consumes it —
the full-precision weight never materializes in HBM (the QLoRA memory
story, adapted to the TPU hierarchy).

Grid: (M/bm, N/bn).  Blocks:
    x       (bm, K)
    packed  (K, bn//2)  uint8  (two nibbles per byte, even|odd columns)
    scales  (K, bn//qblock) f32 (blockwise absmax)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, p_ref, s_ref, o_ref, *, qblock: int):
    x = x_ref[...].astype(jnp.float32)               # (bm, K)
    packed = p_ref[...]                              # (K, bn//2) uint8
    lo = (packed & 0xF).astype(jnp.int32) - 8        # even cols
    hi = (packed >> 4).astype(jnp.int32) - 8         # odd cols
    K, half = packed.shape
    q = jnp.stack([lo, hi], axis=-1).reshape(K, half * 2).astype(jnp.float32)
    s = s_ref[...]                                   # (K, bn//qblock)
    w = (q.reshape(K, half * 2 // qblock, qblock)
         * s[..., None]).reshape(K, half * 2)
    o_ref[...] = jnp.dot(x, w, preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("qblock", "bm", "bn", "interpret"))
def int4_matmul(x, packed, scales, *, qblock: int = 64, bm: int = 128,
                bn: int = 256, interpret: bool = True):
    """x (M,K) @ dequant(packed (K,N//2), scales (K,N//qblock)) → (M,N)."""
    M, K = x.shape
    N = packed.shape[1] * 2
    bm, bn = min(bm, M), min(bn, N)
    while M % bm:
        bm //= 2
    while N % bn or bn % qblock:
        bn //= 2
    assert N % bn == 0 and bn % qblock == 0 and M % bm == 0
    grid = (M // bm, N // bn)
    return pl.pallas_call(
        functools.partial(_kernel, qblock=qblock),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bn // 2), lambda i, j: (0, j)),
            pl.BlockSpec((K, bn // qblock), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(x, packed, scales)
