"""GSPMD sharding rules: FSDP along 'data', tensor-parallel along 'model',
pure data-parallel along 'pod' (DCN).  Rules are keyed by parameter leaf
name (we own every name; see models/*).

The quantum federated fast path adds a fourth axis, ``'clients'``: the
batched round engine's ``(C, …)`` client stacks are embarrassingly
parallel along their leading dimension (per-client independence until
the host-side aggregation — see ``core/batched_engine.py``), so the
``client_*`` helpers below shard exactly that axis across a 1-D device
mesh and replicate everything else.  Client counts that do not divide
the mesh are handled by **explicit padding** (``pad_client_count``) —
``put_client_stacks`` refuses ragged placement rather than silently
resharding.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

FSDP = "data"
TP = "model"
CLIENTS = "clients"

# leaf name -> (in_axis, out_axis) for 2D weights (stacked group dim prepended
# automatically).  None = replicated on that dim.
_DENSE_RULES = {
    "wq": (FSDP, TP), "wkv": (FSDP, TP), "xwq": (FSDP, TP), "xwkv": (FSDP, TP),
    "wo": (TP, FSDP), "xwo": (TP, FSDP),
    "w_in": (FSDP, TP), "w_out": (TP, FSDP),
    "shared_w_in": (FSDP, TP), "shared_w_out": (TP, FSDP),
    "up_proj": (FSDP, TP), "down_proj": (TP, FSDP),
    "in_proj": (FSDP, TP), "out_proj": (TP, FSDP),
    "w_gates": (FSDP, TP),
    "wq_a": (FSDP, None), "wq_b": (None, TP),
    "wkv_a": (FSDP, None), "wkv_b": (None, TP),
    "router": (FSDP, None),
    "x_proj": (TP, None), "dt_w": (None, TP),
    "wk": (FSDP, TP), "wv": (FSDP, TP),
    "w_if": (TP, None),
    "embed": (TP, FSDP),          # vocab on model, d on data
    "lm_head": (FSDP, TP),        # d on data, vocab on model
    "proj_frontend": (FSDP, TP),
}

# 3D expert weights: (E, in, out)
_MOE_RULES = {"w_in": (TP, FSDP, None), "w_out": (TP, None, FSDP)}

_SPECIAL = {
    "conv_w": (None, TP),
    "A_log": (TP, None),
    "r_gates": (None, None, None),
}


def _leaf_spec(name: str, shape: Tuple[int, ...], stacked: bool) -> P:
    nd = len(shape) - (1 if stacked else 0)
    base: Tuple
    if name.endswith("__q"):
        # QLoRA packed int4: same layout as the base weight (out dim
        # halved — divisibility fitting handles the rest)
        in_ax, out_ax = _DENSE_RULES.get(name[:-3], (None, None))
        base = (in_ax, out_ax)
    elif name.endswith("__s"):
        # blockwise scales: shard the in dim like the weight
        in_ax, _ = _DENSE_RULES.get(name[:-3], (None, None))
        base = (in_ax, None)
    elif name.endswith("_lora_a"):
        tgt = name[: -len("_lora_a")]
        in_ax = _DENSE_RULES.get(tgt, (None, None))[0]
        base = (in_ax, None)
    elif name.endswith("_lora_b"):
        tgt = name[: -len("_lora_b")]
        out_ax = _DENSE_RULES.get(tgt, (None, None))[1]
        base = (None, out_ax)
    elif name in _SPECIAL and nd == len(_SPECIAL[name]):
        base = _SPECIAL[name]
    elif nd == 3 and name in _MOE_RULES:
        base = _MOE_RULES[name]
    elif nd == 2 and name in _DENSE_RULES:
        base = _DENSE_RULES[name]
    else:
        base = (None,) * nd       # norms, biases, scalars: replicated
    if stacked:
        base = (None,) + tuple(base)
    return P(*base)


def _filter_axes(spec: P, axis_names) -> P:
    """Drop mesh axes that do not exist on the current mesh."""
    def ok(e):
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a in axis_names)
            return kept if kept else None
        return e if e in axis_names else None
    return P(*(ok(e) for e in spec))


def _fit_divisibility(spec: P, shape, axis_sizes) -> P:
    """Drop sharding on dims the mesh axes do not divide evenly (e.g. a
    51866-entry vocab over a 16-way 'model' axis).  Axes are dropped from
    the right of a tuple entry until the product divides the dim."""
    if not axis_sizes:
        return spec
    out = []
    for i, e in enumerate(spec):
        if e is None:
            out.append(None)
            continue
        axes = list(e) if isinstance(e, (tuple, list)) else [e]
        while axes:
            prod = 1
            for a in axes:
                prod *= axis_sizes.get(a, 1)
            if shape[i] % prod == 0:
                break
            axes.pop()
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    return P(*out)


def param_specs(params, axis_names=("data", "model"), axis_sizes=None):
    """PartitionSpec tree matching a params pytree.

    Group-stacked subtrees live under keys 'groups' / 'enc_groups'
    (tuples of dicts of (G, ...) arrays); everything else is unstacked.
    ``axis_sizes`` (mesh.shape mapping) enables divisibility fitting.
    """
    def one(name, shape, stacked):
        s = _filter_axes(_leaf_spec(name, shape, stacked), axis_names)
        return _fit_divisibility(s, shape, axis_sizes)

    def walk(tree, stacked):
        if isinstance(tree, dict):
            return {k: (walk(v, stacked) if isinstance(v, (dict, tuple, list))
                        else one(k, v.shape, stacked))
                    for k, v in tree.items()}
        if isinstance(tree, (tuple, list)):
            return type(tree)(walk(v, stacked) for v in tree)
        raise TypeError(type(tree))

    out = {}
    for k, v in params.items():
        if k in ("groups", "enc_groups"):
            out[k] = walk(v, True)
        elif isinstance(v, (dict, tuple, list)):
            out[k] = walk(v, False)
        else:
            out[k] = one(k, v.shape, False)
    return out


def batch_axes(axis_names) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in axis_names)


def _scalar_axis(e):
    """P(('data',)) and P('data') mean the same sharding but no longer
    compare equal in jax — canonicalize 1-tuples to the bare axis name."""
    if isinstance(e, (tuple, list)) and len(e) == 1:
        return e[0]
    return e


def batch_specs(batch, axis_names, *, batch_sharded=True):
    """Spec tree for an input batch: leading dim over ('pod','data')."""
    ba = batch_axes(axis_names) if batch_sharded else ()

    def leaf(x):
        if x.ndim == 0:
            return P()
        if x.shape[0] == 1 or not ba:
            return P(*((None,) * x.ndim))
        return P(_scalar_axis(ba), *((None,) * (x.ndim - 1)))

    return jax.tree.map(leaf, batch)


def cache_specs(cache, axis_names, batch: int, axis_sizes=None):
    """Decode caches: batch over ('pod','data') when divisible, long axes
    (seq) over 'model' where present.  Divisibility-checked when
    ``axis_sizes`` (mesh.shape mapping) is given."""
    ba = batch_axes(axis_names)
    tp = TP if TP in axis_names else None

    def divides(axes, dim):
        if not axis_sizes:
            return True
        prod = 1
        for a in (axes if isinstance(axes, (tuple, list)) else [axes]):
            prod *= axis_sizes.get(a, 1)
        return dim % prod == 0

    def leaf(x):
        spec = [None] * x.ndim
        dims = list(x.shape)
        gdim = 0
        # stacked group axis first (dims[0] == n_groups, small): replicated
        if x.ndim >= 3:
            gdim = 1
        if (batch > 1 and ba and x.ndim > gdim and dims[gdim] == batch
                and divides(ba, batch)):
            spec[gdim] = _scalar_axis(ba)
        # shard the longest remaining axis on model if it's big & divisible
        rest = [(i, d) for i, d in enumerate(dims)
                if i > gdim and d >= 1024 and divides(tp, d)]
        if rest and tp:
            i, _ = max(rest, key=lambda t: t[1])
            spec[i] = tp
        return P(*spec)

    return jax.tree.map(leaf, cache)


def constrain(x, spec: P):
    """with_sharding_constraint if an abstract mesh is available, else no-op.
    Axes that do not exist on the mesh or do not divide the dim are
    dropped (graceful degradation on small smoke meshes)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        fspec = _filter_axes(spec, mesh.axis_names)
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
        fspec = _fit_divisibility(fspec, x.shape, sizes)
        return jax.lax.with_sharding_constraint(x, fspec)
    except Exception:
        return x


def mesh_axis_size(name: str) -> int:
    """Size of a mesh axis under the current abstract mesh (1 if absent)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None:
            return 1
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
        return int(sizes.get(name, 1))
    except Exception:
        return 1


def packed_gather_spec(name: str) -> P:
    """Sharding for a QLoRA-packed weight at its use site: keep the
    'model' (TP) shard, drop the 'data' (FSDP) shard — so the FSDP
    all-gather happens on the PACKED int4 bytes (4× less wire traffic)
    and dequantization runs after the collective."""
    in_ax, out_ax = _DENSE_RULES.get(name, (None, None))
    keep = lambda ax: ax if ax == TP else None
    return P(keep(in_ax), keep(out_ax))


def head_axis_choice(KH: int, G: int) -> tuple:
    """For grouped-attention tensors laid out (..., KH, G, ...): which of
    the two head dims can carry the 'model' axis?  Returns (kh_axis,
    g_axis) — exactly one is 'model' when divisible, favoring KH."""
    tp = mesh_axis_size(TP)
    if tp <= 1:
        return (None, None)
    if KH % tp == 0:
        return (TP, None)
    if G % tp == 0:
        return (None, TP)
    return (None, None)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# 'clients' axis — the batched federated round engine's mesh dimension
# ---------------------------------------------------------------------------
def client_mesh(n_devices: Optional[int] = None) -> Mesh:
    """1-D mesh over the first ``n_devices`` local devices, axis
    ``'clients'``.  ``None`` → all visible devices.  Raises when more
    devices are requested than the platform exposes (force host devices
    with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if n < 1:
        raise ValueError(f"n_devices must be >= 1, got {n}")
    if n > len(devs):
        raise ValueError(
            f"client mesh wants {n} devices but only {len(devs)} are "
            f"visible; set XLA_FLAGS=--xla_force_host_platform_device_"
            f"count={n} (before jax initializes) or lower n_devices")
    return Mesh(np.asarray(devs[:n]), (CLIENTS,))


def pad_client_count(n_clients: int, n_shards: int) -> int:
    """Smallest multiple of ``n_shards`` that is >= ``n_clients`` — the
    padded leading dim of the client stacks.  Padding clients are inert:
    all-zero masks and zero iteration budgets (see the engine's padding
    contract), so they never contribute to losses or aggregation."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    return -(-int(n_clients) // int(n_shards)) * int(n_shards)


def check_client_divisibility(n_clients: int, n_shards: int) -> None:
    """Ragged client axes are an error, not an implicit reshard: pad
    first with ``pad_client_count`` (the engine does this at
    construction) or shrink the mesh."""
    if n_clients % n_shards != 0:
        raise ValueError(
            f"client axis of size {n_clients} does not divide across "
            f"{n_shards} mesh shards; pad to "
            f"{pad_client_count(n_clients, n_shards)} with inert clients "
            f"(pad_client_count) or use a mesh whose 'clients' axis "
            f"divides {n_clients}")


def client_stack_spec(ndim: int) -> P:
    """Spec for a client-stacked array: leading dim on 'clients', the
    rest replicated — (C, Bmax, F) → P('clients', None, None), etc."""
    if ndim < 1:
        return P()
    return P(CLIENTS, *((None,) * (ndim - 1)))


def client_specs(arrays, n_clients: int):
    """Spec tree for a pytree of engine inputs: leaves whose leading dim
    equals ``n_clients`` ride the 'clients' axis, everything else (θ_g,
    scalars) is replicated."""
    def leaf(x):
        if getattr(x, "ndim", 0) >= 1 and x.shape[0] == n_clients:
            return client_stack_spec(x.ndim)
        return P()
    return jax.tree.map(leaf, arrays)


def client_tree_specs(tree, n_clients: int):
    """Spec tree for a **client-stacked pytree** — LoRA adapter stacks,
    vmapped AdamW states: every array leaf must carry the client axis
    leading (``(C, …)``), and a leaf that does not is an error, not a
    silent replication.  (``client_specs`` is the permissive variant for
    mixed input bundles where θ_g-like leaves are legitimately
    replicated; for an adapter stack a non-client leaf means someone
    forgot to vmap the init.)"""
    def leaf(x):
        if getattr(x, "ndim", 0) < 1 or x.shape[0] != n_clients:
            raise ValueError(
                f"client-stacked pytree leaf has shape "
                f"{getattr(x, 'shape', ())}, expected leading dim "
                f"{n_clients}; stack per-client state with jax.vmap "
                f"before placement")
        return client_stack_spec(x.ndim)
    return jax.tree.map(leaf, tree)


def put_client_tree(mesh: Mesh, tree, n_clients: int):
    """Place a client-stacked pytree (adapters / optimizer states) on the
    'clients' mesh — strict: every leaf sharded along its leading client
    axis (``client_tree_specs``)."""
    check_client_divisibility(n_clients, mesh.shape[CLIENTS])
    specs = client_tree_specs(tree, n_clients)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        tree, specs)


def put_replicated(mesh: Mesh, x):
    """Explicitly replicate an array (or pytree — e.g. the frozen LLM
    base) on every mesh device — for inputs like θ_g whose leading dim
    could coincidentally equal the padded client count (shape inference
    must never shard them)."""
    return jax.tree.map(
        lambda v: jax.device_put(v, NamedSharding(mesh, P())), x)


def put_client_stacks(mesh: Mesh, arrays, n_clients: int):
    """Place a pytree of engine inputs on ``mesh``: client-stacked leaves
    sharded along 'clients', the rest replicated.  The jitted round
    program then partitions along the client axis by computation-follows-
    data — no in_shardings plumbing at every call site.

    Population stacks (the fused driver's ``(C_pop, …)`` parameter /
    budget / loss arrays, C_pop ≫ the per-round cohort) place through
    this same helper: the population axis IS the client axis, padded
    with ``pad_client_count`` like any other ragged client count.  The
    round cohort gathered *from* them inside the fused program needs
    ``constrain_client_axis`` — see below."""
    check_client_divisibility(n_clients, mesh.shape[CLIENTS])
    specs = client_specs(arrays, n_clients)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        arrays, specs)


def constrain_replicated(x, mesh: Optional[Mesh]):
    """Pin a traced array to full replication inside a jitted program;
    no-op when ``mesh is None``.  The fused population driver keeps its
    ``(C_pop, …)`` carry arrays replicated (see the placement tradeoff
    in ``core/fused_rounds.py``), and a scatter of sharded per-cohort
    values into them would otherwise let GSPMD pick an output sharding
    that drifts between scan iterations."""
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P()))


def constrain_client_axis(x, mesh: Optional[Mesh]):
    """Pin a **traced** client-stacked array to the 'clients' axis inside
    a jitted program (``with_sharding_constraint``); no-op when
    ``mesh is None`` (the single-device path).

    Computation-follows-data covers arrays that enter the program with a
    placement, but the fused round driver *gathers* its per-round cohort
    stacks out of the ``(C_pop, …)`` population by traced indices — a
    dynamic gather whose output sharding GSPMD is free to resolve as
    replicated, which would serialize the whole local phase on one
    device.  Constraining the gathered ``(C_round, …)`` stacks (leading
    dim on 'clients', rest replicated, i.e. ``client_stack_spec``)
    restores the per-client partitioning the round program is built
    around.  ``C_round`` must divide the mesh — the fused driver
    enforces that at construction."""
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, client_stack_spec(getattr(x, "ndim", 0))))
