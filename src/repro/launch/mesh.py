"""Production mesh builders.

Functions, not module-level constants — importing this module never touches
jax device state (required: smoke tests must see 1 device; only
``dryrun.py`` forces 512 host devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: 16×16 = 256 chips/pod; 2 pods = 512 chips via DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Single-device mesh for CPU smoke/integration runs."""
    return jax.make_mesh((1, 1), ("data", "model"))


# Hardware constants (TPU v5e) for the roofline model — see EXPERIMENTS.md.
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
