"""HLO post-processing: collective byte extraction + roofline terms.

``cost_analysis()`` gives per-device FLOPs/bytes of the partitioned module;
collective traffic is NOT included there, so we parse the compiled HLO text
and sum output-buffer sizes of every collective op (per-device view —
matches the per-chip denominator convention in EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import re
from typing import Dict

from repro.launch import mesh as mesh_mod

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(
    r"(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|f64|c64|c128)"
    r"\[([\d,]*)\]")

_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective kind: {count, bytes} (per-device output bytes)."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        type_str, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-start":
            continue      # async pair: the -done op carries the result type
        out[kind]["count"] += 1
        out[kind]["bytes"] += _shape_bytes(type_str)
    return out


def total_collective_bytes(hlo_text: str) -> int:
    return sum(v["bytes"] for v in collective_stats(hlo_text).values())


# -- trip-count-aware attribution -------------------------------------------
def _computation_spans(hlo_text: str):
    """[(name, start, end)] for every top-level computation block."""
    spans = []
    cur_name, cur_start = None, None
    for line_m in re.finditer(r"^.*$", hlo_text, re.M):
        line = line_m.group(0)
        if (line.startswith("%") or line.startswith("ENTRY ")) \
                and line.rstrip().endswith("{"):
            raw = line[6:] if line.startswith("ENTRY ") else line
            name = raw.lstrip("%").split(" ")[0].split("(")[0]
            cur_name, cur_start = name, line_m.end()
        elif line.startswith("}") and cur_name is not None:
            spans.append((cur_name, cur_start, line_m.start()))
            cur_name = None
    return spans


_WHILE_BODY_RE = re.compile(
    r"while\(%?[\w\.\-]+\), condition=%?[\w\.\-]+, body=%?([\w\.\-]+)")
_TRIPS_RE = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")


def loop_multipliers(hlo_text: str) -> Dict[str, int]:
    """Execution multiplier per computation: product of known_trip_count of
    every enclosing while loop (ENTRY = 1).  XLA stamps known_trip_count in
    each while op's backend_config."""
    spans = _computation_spans(hlo_text)
    edges: Dict[str, tuple] = {}      # body -> (parent computation, trips)
    call_re = re.compile(r"(?:calls=|to_apply=)%?([\w\.\-]+)")
    called = set()                    # fusion/apply bodies (no HBM writes)
    for name, s, e in spans:
        for line in hlo_text[s:e].splitlines():
            m = _WHILE_BODY_RE.search(line)
            if m:
                t = _TRIPS_RE.search(line)
                edges[m.group(1)] = (name, int(t.group(1)) if t else 1)
                continue
            # fusion/call/reduce bodies inherit the caller's multiplier
            for cm in call_re.finditer(line):
                edges.setdefault(cm.group(1), (name, 1))
                called.add(cm.group(1))
    loop_multipliers._called = called      # consumed by weighted_hlo_cost

    mult: Dict[str, int] = {}

    def resolve(comp: str, depth=0) -> int:
        if comp in mult:
            return mult[comp]
        if comp not in edges or depth > 64:
            mult[comp] = 1
            return 1
        parent, trips = edges[comp]
        mult[comp] = trips * resolve(parent, depth + 1)
        return mult[comp]

    for name, _, _ in spans:
        resolve(name)
    return mult


def collective_stats_weighted(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Like collective_stats but each op is weighted by the product of the
    trip counts of its enclosing while loops — the *dynamic* per-step
    traffic (what the roofline wants)."""
    mult = loop_multipliers(hlo_text)
    spans = _computation_spans(hlo_text)
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for name, s, e in spans:
        w = mult.get(name, 1)
        for m in _OP_RE.finditer(hlo_text[s:e]):
            type_str, kind, suffix = m.group(1), m.group(2), m.group(3)
            if suffix == "-start":
                continue
            out[kind]["count"] += w
            out[kind]["bytes"] += _shape_bytes(type_str) * w
    return out


_DOT_RE = re.compile(
    r"= (\S+) dot\(.*?lhs_contracting_dims=\{([\d,]*)\}", re.S)
_OP_LINE_RE = re.compile(r"^\s+(%?[\w\.\-]+) = (\S+?) ([\w\-]+)\(", re.M)
_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id",
             "iota", "while", "conditional", "call", "custom-call"}


def _first_shape(type_str: str):
    """(dtype, dims) of the first tensor in a result type string."""
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d] or [1]
    return m.group(1), dims


def weighted_hlo_cost(hlo_text: str, *,
                      inner_mult_cutoff: int = 0) -> Dict[str, float]:
    """Exact trip-weighted dynamic cost from the compiled HLO:

    - flops: every ``dot`` op — 2 × prod(result dims) × K, where K is the
      product of the lhs contracting dims — × enclosing-loop trip counts.
      (Elementwise flops are ignored: MXU dots dominate by >100×.)
    - bytes: Σ over materializing ops of result bytes × trips × 2
      (a write + downstream read per materialized buffer — the standard
      HBM-traffic proxy when fusion interiors are invisible).
    - bytes_outer: same sum restricted to ops whose loop multiplier is ≤
      ``inner_mult_cutoff`` — buffers inside deeper loop nests are
      attention-chunk tiles that the Pallas flash kernel keeps in VMEM on
      the TPU target; bytes_outer models that deployment.
    """
    mult = loop_multipliers(hlo_text)
    called = getattr(loop_multipliers, "_called", set())
    spans = _computation_spans(hlo_text)
    flops = 0.0
    bytes_ = 0.0
    bytes_outer = 0.0
    for name, s, e in spans:
        w = mult.get(name, 1)
        in_fusion_body = name in called      # interior: no HBM traffic
        body = hlo_text[s:e]
        # symbol table: op name -> result type string (incl. parameters)
        types = {}
        for line in body.splitlines():
            tm = re.match(r"\s+(%?[\w\.\-]+) = (\([^=]*?\)|\S+?) [\w\-]+\(",
                          line)
            if tm:
                types[tm.group(1).lstrip("%")] = tm.group(2)
        for line in body.splitlines():
            om = _OP_LINE_RE.match(line)
            if not om:
                continue
            opkind = om.group(3)
            if opkind in _SKIP_OPS:
                continue
            if not in_fusion_body:
                b = _shape_bytes(om.group(2)) * w * 2
                bytes_ += b
                if not inner_mult_cutoff or w <= inner_mult_cutoff:
                    bytes_outer += b
            if opkind == "dot":
                fs = _first_shape(om.group(2))
                if fs is None:
                    continue
                _, out_dims = fs
                out_n = 1
                for d in out_dims:
                    out_n *= d
                lhs_m = re.search(r"dot\(%?([\w\.\-]+)", line)
                km = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                K = 1
                if lhs_m and km:
                    lt = types.get(lhs_m.group(1))
                    if lt:
                        lfs = _first_shape(lt)
                        if lfs:
                            lhs_dims = lfs[1]
                            for ci in km.group(1).split(","):
                                if ci and int(ci) < len(lhs_dims):
                                    K *= lhs_dims[int(ci)]
                flops += 2.0 * out_n * K * w
    return {"flops": flops, "bytes": bytes_, "bytes_outer": bytes_outer}


def roofline_terms(*, flops_per_chip: float, hbm_bytes_per_chip: float,
                   collective_bytes_per_chip: float) -> Dict[str, float]:
    """Three-term roofline (seconds).  Inputs are per-chip quantities from
    the partitioned module, so no further division by chip count."""
    compute = flops_per_chip / mesh_mod.PEAK_FLOPS_BF16
    memory = hbm_bytes_per_chip / mesh_mod.HBM_BW
    collective = collective_bytes_per_chip / mesh_mod.ICI_BW
    dom = max((("compute", compute), ("memory", memory),
               ("collective", collective)), key=lambda t: t[1])[0]
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": collective, "dominant": dom}


def remat_duplication(hlo_text: str) -> float:
    """Heuristic recompute indicator: ratio of fusion ops to unique fusion
    signatures (1.0 = no duplicate computation)."""
    sigs = re.findall(r"fusion\(([^)]*)\)", hlo_text)
    if not sigs:
        return 1.0
    return len(sigs) / max(1, len(set(sigs)))
