"""Federated training driver (production CLI for the paper's experiments).

  PYTHONPATH=src python -m repro.launch.train --task genomic \
      --method llm-qfl --rounds 8 --clients 5 --backend aersim \
      --select-frac 0.2 --regulation adaptive --out experiments/runs/demo

Writes run history (per-round JSON) + final summary.  This is Algorithm 1
end-to-end: synthetic-data build → round-1 LLM LoRA fine-tuning →
regulated quantum training → aggregation → termination.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

from repro.core import RunConfig, Orchestrator
from repro.data.tasks import build_task


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="genomic",
                    choices=["genomic", "tweets"])
    ap.add_argument("--method", default="llm-qfl",
                    choices=["qfl", "llm-qfl"])
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--clients", type=int, default=5)
    ap.add_argument("--train-size", type=int, default=250)
    ap.add_argument("--select-frac", type=float, default=1.0)
    ap.add_argument("--regulation", default="adaptive")
    ap.add_argument("--maxiter0", type=int, default=10)
    ap.add_argument("--optimizer", default="nelder-mead",
                    choices=["nelder-mead", "spsa"])
    ap.add_argument("--engine", default="sequential",
                    choices=["sequential", "batched"])
    ap.add_argument("--n-qubits", type=int, default=4)
    ap.add_argument("--backend", default="exact",
                    choices=["exact", "fake", "aersim", "real"])
    ap.add_argument("--llm", default="tiny-llm")
    ap.add_argument("--llm-steps", type=int, default=30)
    ap.add_argument("--non-iid-alpha", type=float, default=0.0)
    ap.add_argument("--epsilon", type=float, default=1e-3)
    ap.add_argument("--no-early-stop", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    t0 = time.time()
    task = build_task(args.task, n_clients=args.clients,
                      train_size=args.train_size,
                      non_iid_alpha=args.non_iid_alpha, seed=args.seed,
                      n_features=args.n_qubits)
    rc = RunConfig(
        method=args.method, select_frac=args.select_frac,
        regulation=args.regulation, maxiter0=args.maxiter0,
        n_rounds=args.rounds, epsilon=args.epsilon,
        optimizer=args.optimizer, engine=args.engine,
        n_qubits=args.n_qubits, backend=args.backend,
        llm_name=args.llm, llm_steps=args.llm_steps,
        early_stop=not args.no_early_stop, seed=args.seed)
    res = Orchestrator(task, rc).run()

    for r in res.rounds:
        print(f"round {r.t:3d}  server_loss={r.server_loss:.4f} "
              f"val_acc={r.server_val_acc:.3f} "
              f"test_acc={r.server_test_acc:.3f} "
              f"maxiters={r.maxiters} selected={r.selected}")
    print(f"done in {time.time()-t0:.1f}s "
          f"(LLM fine-tune {res.llm_finetune_time_s:.1f}s, "
          f"early_stop={res.terminated_early})")

    if args.out:
        out = Path(args.out)
        out.mkdir(parents=True, exist_ok=True)
        hist = {
            "config": dataclasses.asdict(rc),
            "rounds": [dataclasses.asdict(r) for r in res.rounds],
            "llm_losses": res.llm_losses, "llm_f1": res.llm_f1,
            "terminated_early": res.terminated_early,
            "theta_g": [float(x) for x in res.theta_g],
        }
        (out / "history.json").write_text(json.dumps(hist, indent=1))
        print(f"history → {out/'history.json'}")
    return res


if __name__ == "__main__":
    main()
