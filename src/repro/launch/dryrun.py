"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) and
extract memory / cost / collective statistics.

The ``os.environ`` line below MUST stay before any other import — jax locks
the device count on first init, and the production meshes need 512 host
devices.  Smoke tests and benchmarks never import this module, so they see
1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all   # every pair
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import get, pairs
from repro.distributed import sharding as shd
from repro.launch import hlo_analysis as hlo
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.optim import adamw

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def default_n_micro(arch: str, dp: int, global_batch: int) -> int:
    """1 example per device per microstep for ≥10B-class; fewer microsteps
    for small models (no memory pressure)."""
    small = {"xlstm-125m", "stablelm-3b", "whisper-large-v3",
             "minicpm3-4b", "starcoder2-7b"}
    per_dev = max(1, global_batch // dp)
    if arch in small:
        return max(1, per_dev // 4)
    return per_dev


def decode_window(cfg, shape_name: str) -> int:
    if shape_name == "long_500k":
        return cfg.long_decode_window
    return cfg.sliding_window


def build_step(cfg, shape, mesh, *, n_micro=None, seq_parallel=True,
               loss_chunk=512, mlstm_chunkwise=False, window=None,
               attn_anchor=True):
    """Returns (jitted_fn, abstract_args) ready to .lower(*args)."""
    axis_names = mesh.axis_names
    dp = 1
    for a in ("pod", "data"):
        if a in axis_names:
            dp *= mesh.shape[a]

    def _init_all(k):
        p = M.init_params(cfg, k)
        return p, M.init_adapters(cfg, k, p)

    aparams, aadapters = jax.eval_shape(_init_all, jax.random.PRNGKey(0))
    axis_sizes = dict(mesh.shape)
    pspecs = shd.param_specs(aparams, axis_names, axis_sizes)
    aspecs = shd.param_specs(aadapters, axis_names, axis_sizes)
    psh = shd.named(mesh, pspecs)
    ash = shd.named(mesh, aspecs)

    if shape.kind == "train":
        nm = n_micro or default_n_micro(cfg.name, dp, shape.global_batch)
        opts = M.FwdOptions(
            remat=True, seq_parallel=seq_parallel,
            mlstm_chunkwise=mlstm_chunkwise,
            attn_anchor=attn_anchor,
            window=window if window is not None else
            (cfg.sliding_window or None))
        step = M.make_train_step(cfg, n_microbatches=nm, opts=opts,
                                 loss_chunk=loss_chunk)
        aopt = jax.eval_shape(adamw.init, aadapters)
        osh = adamw.AdamWState(
            step=NamedSharding(mesh, P()),
            mu=shd.named(mesh, shd.param_specs(aadapters, axis_names,
                                               axis_sizes)),
            nu=shd.named(mesh, shd.param_specs(aadapters, axis_names,
                                               axis_sizes)))
        batch = M.input_specs(cfg, shape)
        bsh = shd.named(mesh, shd.batch_specs(batch, axis_names))
        fn = jax.jit(step, in_shardings=(psh, ash, osh, bsh),
                     donate_argnums=(1, 2))
        return fn, (aparams, aadapters, aopt, batch), {"n_micro": nm}

    if shape.kind == "prefill":
        opts = M.FwdOptions(remat=False, collect_cache=True,
                            shard_cache=True, seq_parallel=seq_parallel,
                            attn_anchor=attn_anchor,
                            window=window if window is not None else
                            (cfg.sliding_window or None))
        step = M.make_prefill_step(cfg, opts)
        batch = M.input_specs(cfg, shape)
        bsh = shd.named(mesh, shd.batch_specs(batch, axis_names))
        fn = jax.jit(step, in_shardings=(psh, ash, bsh))
        return fn, (aparams, aadapters, batch), {}

    if shape.kind == "decode":
        w = window if window is not None else decode_window(cfg, shape.name)
        step = M.make_serve_step(cfg, window=w)
        spec = M.input_specs(cfg, shape, window=w)
        cache, token, pos = spec["cache"], spec["token"], spec["pos"]
        csh = shd.named(mesh, shd.cache_specs(cache, axis_names,
                                              shape.global_batch,
                                              axis_sizes))
        tsh = shd.named(mesh, shd.batch_specs(
            {"token": token}, axis_names))["token"]
        fn = jax.jit(step, in_shardings=(psh, ash, csh, tsh,
                                         NamedSharding(mesh, P())),
                     donate_argnums=(2,))
        return fn, (aparams, aadapters, cache, token, pos), {"window": w}

    raise ValueError(shape.kind)


def run_one(arch: str, shape_name: str, mesh_kind: str, *, tag="baseline",
            save=True, qlora=False, **knobs):
    import dataclasses
    cfg = get(arch)
    if qlora:
        cfg = dataclasses.replace(
            cfg, lora=dataclasses.replace(cfg.lora, quantize_base=True))
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
           "knobs": knobs, "status": "ok"}
    t0 = time.time()
    try:
        with jax.set_mesh(mesh):
            fn, args, extra = build_step(cfg, shape, mesh, **knobs)
            rec.update(extra)
            lowered = fn.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            txt = compiled.as_text()
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)
        rec["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_per_device": (ma.argument_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      + ma.output_size_in_bytes
                                      - ma.alias_size_in_bytes),
        }
        rec["cost"] = {"flops_per_device": ca.get("flops", 0.0),
                       "bytes_per_device": ca.get("bytes accessed", 0.0),
                       "transcendentals": ca.get("transcendentals", 0.0)}
        coll = hlo.collective_stats(txt)
        rec["collectives"] = coll
        cbytes = sum(v["bytes"] for v in coll.values())
        # Collectives: EXACT dynamic traffic via known_trip_count-weighted
        # attribution (each op × product of enclosing while trip counts).
        coll_w = hlo.collective_stats_weighted(txt)
        rec["collectives_weighted"] = coll_w
        cbytes_w = sum(v["bytes"] for v in coll_w.values())
        # FLOPs/bytes: XLA's cost analysis counts a while body ONCE — our
        # step scans layer groups and microbatches, so we compute exact
        # trip-weighted dot FLOPs and a materialized-buffer HBM-traffic
        # proxy straight from the HLO (see hlo_analysis.weighted_hlo_cost).
        trips = cfg.n_groups * max(1, int(extra.get("n_micro", 1)))
        wc = hlo.weighted_hlo_cost(txt, inner_mult_cutoff=trips)
        rec["scan_trips"] = trips
        rec["cost_corrected"] = {
            "flops_per_device": wc["flops"],
            "bytes_per_device": wc["bytes"],
            "bytes_outer_per_device": wc["bytes_outer"],
            "collective_bytes_per_device": cbytes_w,
        }
        rec["roofline_raw"] = hlo.roofline_terms(
            flops_per_chip=ca.get("flops", 0.0),
            hbm_bytes_per_chip=ca.get("bytes accessed", 0.0),
            collective_bytes_per_chip=cbytes)
        # memory term uses bytes_outer — inner attention-chunk tiles are
        # VMEM-resident under the Pallas flash kernel on the TPU target
        # (the all-buffers figure is kept in cost_corrected for reference)
        rec["roofline"] = hlo.roofline_terms(
            flops_per_chip=rec["cost_corrected"]["flops_per_device"],
            hbm_bytes_per_chip=rec["cost_corrected"][
                "bytes_outer_per_device"],
            collective_bytes_per_chip=rec["cost_corrected"][
                "collective_bytes_per_device"])
        rec["model_flops"] = model_flops(cfg, shape)
        hw = (rec["cost_corrected"]["flops_per_device"]
              * total_chips(mesh))
        rec["useful_flops_ratio"] = (rec["model_flops"] / hw) if hw else 0.0
        rec["hlo_bytes"] = len(txt)
    except Exception as e:  # noqa: BLE001 — record failures, don't die
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    if save:
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        name = f"{arch}_{shape_name}_{mesh_kind}_{tag}.json"
        (OUT_DIR / name).write_text(json.dumps(rec, indent=1))
    return rec


def total_chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n


def model_flops(cfg, shape) -> float:
    """6·N·D (train) / 2·N·D (inference) with N = active params."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # decode: one token


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--loss-chunk", type=int, default=512)
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--mlstm-chunkwise", action="store_true")
    ap.add_argument("--window", type=int, default=None)
    ap.add_argument("--no-attn-anchor", action="store_true")
    ap.add_argument("--qlora", action="store_true")
    args = ap.parse_args()

    knobs = dict(n_micro=args.n_micro, loss_chunk=args.loss_chunk,
                 seq_parallel=not args.no_seq_parallel,
                 mlstm_chunkwise=args.mlstm_chunkwise, window=args.window,
                 attn_anchor=not args.no_attn_anchor, qlora=args.qlora)

    if args.all:
        todo = [(a, s, m) for (a, s) in pairs()
                for m in ("single", "multi")]
    else:
        todo = [(args.arch, args.shape, args.mesh)]

    for (a, s, m) in todo:
        t0 = time.time()
        rec = run_one(a, s, m, tag=args.tag, **knobs)
        status = rec["status"]
        extra = ""
        if status == "ok":
            mem = rec["memory"]["peak_bytes_per_device"] / 2**30
            dom = rec["roofline"]["dominant"]
            extra = f"peak={mem:.2f}GiB/dev dominant={dom}"
        else:
            extra = rec["error"][:160]
        print(f"[{time.time()-t0:7.1f}s] {a} × {s} × {m}: {status} {extra}",
              flush=True)


if __name__ == "__main__":
    main()
