"""xLSTM mixers: mLSTM (matrix memory) and sLSTM (scalar memory).

Both follow the stabilized exponential-gating formulation of
arXiv:2405.04517.  Training runs a sequential ``lax.scan`` over time — HLO
is compact; the chunkwise-parallel mLSTM formulation is a §Perf lever
implemented in ``mlstm_train_chunkwise`` (beyond-paper optimization).
Decode is a single O(1) recurrent update, making long_500k natural.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense, lora_pair, rms_norm


def _group_norm(x, scale, heads, eps=1e-5):
    """Per-head group norm over the head feature dim.  x: (..., ed)."""
    shp = x.shape
    xh = x.reshape(*shp[:-1], heads, shp[-1] // heads).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(shp) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_params(key, cfg, dtype):
    import jax.random as jr
    from repro.models.common import init_dense
    xc, d, H = cfg.xlstm, cfg.d_model, cfg.n_heads
    ed = xc.expand * d
    ks = jr.split(key, 7)
    return {
        "ln": jnp.ones((d,), dtype),
        "up_proj": init_dense(ks[0], (d, 2 * ed), dtype),
        "conv_w": init_dense(ks[1], (xc.conv_width, ed), dtype, scale=0.5),
        "conv_b": jnp.zeros((ed,), dtype),
        "wq": init_dense(ks[2], (ed, ed), dtype),
        "wk": init_dense(ks[3], (ed, ed), dtype),
        "wv": init_dense(ks[4], (ed, ed), dtype),
        "w_if": init_dense(ks[5], (ed, 2 * H), jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]),
        "gn": jnp.ones((ed,), dtype),
        "down_proj": init_dense(ks[6], (ed, d), dtype,
                                scale=0.5 / (d ** 0.5 * cfg.n_layers ** 0.5)),
    }


def _mlstm_qkvif(params, cfg, x):
    from repro.models.ssm import _causal_conv
    xc, H = cfg.xlstm, cfg.n_heads
    B, S, d = x.shape
    ed = xc.expand * d
    D = ed // H
    xn = rms_norm(x, params["ln"], cfg.norm_eps)
    xu = dense(xn, params["up_proj"], lora_pair(params, "up_proj", cfg.lora))
    x_in, z = jnp.split(xu, 2, axis=-1)
    x_c = jax.nn.silu(_causal_conv(x_in, params["conv_w"], params["conv_b"]))
    q = dense(x_c, params["wq"], lora_pair(params, "wq", cfg.lora))
    k = dense(x_c, params["wk"], lora_pair(params, "wk", cfg.lora))
    v = dense(x_in, params["wv"], lora_pair(params, "wv", cfg.lora))
    q = q.reshape(B, S, H, D).astype(jnp.float32)
    k = k.reshape(B, S, H, D).astype(jnp.float32) * (D ** -0.5)
    v = v.reshape(B, S, H, D).astype(jnp.float32)
    gif = x_c.astype(jnp.float32) @ params["w_if"] + params["b_if"]
    li = gif[..., :H]                                  # log input gate (B,S,H)
    lf = jax.nn.log_sigmoid(gif[..., H:])              # log forget gate
    return z, q, k, v, li, lf


def _mlstm_out(params, cfg, x, h, z):
    B, S, _, _ = h.shape
    ed = h.shape[-1] * cfg.n_heads
    hflat = _group_norm(h.reshape(B, S, ed).astype(x.dtype), params["gn"],
                        cfg.n_heads)
    y = hflat * jax.nn.silu(z)
    return x + dense(y, params["down_proj"],
                     lora_pair(params, "down_proj", cfg.lora))


def mlstm_train(params, cfg, x) -> Tuple[jnp.ndarray, Tuple]:
    """Sequential-scan mLSTM (paper-faithful baseline).  x: (B,S,d)."""
    z, q, k, v, li, lf = _mlstm_qkvif(params, cfg, x)
    B, S, H, D = q.shape

    def step(carry, t):
        C, n, m = carry                                # (B,H,D,D),(B,H,D),(B,H)
        qt, kt, vt = q[:, t], k[:, t], v[:, t]
        m_new = jnp.maximum(lf[:, t] + m, li[:, t])
        fp = jnp.exp(lf[:, t] + m - m_new)[..., None]
        ip = jnp.exp(li[:, t] - m_new)[..., None]
        C = fp[..., None] * C + ip[..., None] * (kt[..., :, None]
                                                 * vt[..., None, :])
        n = fp * n + ip * kt
        num = jnp.einsum("bhdk,bhd->bhk", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt)),
                          jnp.exp(-m_new))[..., None]
        h = num / den
        return (C, n, m_new), h

    C0 = jnp.zeros((B, H, D, D), jnp.float32)
    n0 = jnp.zeros((B, H, D), jnp.float32)
    m0 = jnp.zeros((B, H), jnp.float32)
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), jnp.arange(S))
    h = hs.transpose(1, 0, 2, 3)                       # (B,S,H,D)
    return _mlstm_out(params, cfg, x, h, z), (C, n, m)


def mlstm_train_chunkwise(params, cfg, x, *, chunk: int = 64
                          ) -> Tuple[jnp.ndarray, Tuple]:
    """Chunkwise-parallel mLSTM (beyond-paper §Perf path): intra-chunk
    attention-style parallelism + inter-chunk state recurrence.  Numerically
    equivalent to ``mlstm_train`` (validated in tests)."""
    z, q, k, v, li, lf = _mlstm_qkvif(params, cfg, x)
    B, S, H, D = q.shape
    cs = min(chunk, S)
    assert S % cs == 0
    nc = S // cs

    qs = q.reshape(B, nc, cs, H, D).transpose(1, 0, 3, 2, 4)  # (nc,B,H,cs,D)
    ks = k.reshape(B, nc, cs, H, D).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(B, nc, cs, H, D).transpose(1, 0, 3, 2, 4)
    lis = li.reshape(B, nc, cs, H).transpose(1, 0, 3, 2)      # (nc,B,H,cs)
    lfs = lf.reshape(B, nc, cs, H).transpose(1, 0, 3, 2)

    def chunk_step(carry, inp):
        C, n, m = carry                                # scaled state, log-scale m
        qc, kc, vc, lic, lfc = inp
        F = jnp.cumsum(lfc, axis=-1)                   # inclusive (B,H,cs)
        # intra-chunk log weights  b[t,s] = F_t - F_s + li_s  (s ≤ t)
        bmat = F[..., :, None] - F[..., None, :] + lic[..., None, :]
        tri = jnp.tril(jnp.ones((cs, cs), bool))
        bmat = jnp.where(tri, bmat, -jnp.inf)
        # inter-chunk log weight for each t: a_t = F_t (+ carry scale m)
        a = F + m[..., None]
        m_t = jnp.maximum(bmat.max(-1), a)             # per-position stabilizer
        intra = jnp.exp(bmat - m_t[..., None])         # (B,H,cs,cs)
        inter = jnp.exp(a - m_t)                       # (B,H,cs)
        scores = jnp.einsum("bhtd,bhsd->bhts", qc, kc) * intra
        num = (jnp.einsum("bhts,bhsd->bhtd", scores, vc)
               + inter[..., None] * jnp.einsum("bhtd,bhdk->bhtk", qc, C))
        den_vec = (scores.sum(-1)
                   + inter * jnp.einsum("bhtd,bhd->bht", qc, n))
        den = jnp.maximum(jnp.abs(den_vec), jnp.exp(-m_t))[..., None]
        h = num / den                                  # (B,H,cs,D)
        # state update to end of chunk
        F_last = F[..., -1:]
        m_new = jnp.maximum(F_last[..., 0] + m,
                            (F_last - F + lic).max(-1))
        w_in = jnp.exp(F_last - F + lic - m_new[..., None])   # (B,H,cs)
        C_new = (jnp.exp(F_last[..., 0] + m - m_new)[..., None, None] * C
                 + jnp.einsum("bhs,bhsd,bhsk->bhdk", w_in, kc, vc))
        n_new = (jnp.exp(F_last[..., 0] + m - m_new)[..., None] * n
                 + jnp.einsum("bhs,bhsd->bhd", w_in, kc))
        return (C_new, n_new, m_new), h

    C0 = jnp.zeros((B, H, D, D), jnp.float32)
    n0 = jnp.zeros((B, H, D), jnp.float32)
    m0 = jnp.zeros((B, H), jnp.float32)
    (C, n, m), hs = jax.lax.scan(chunk_step, (C0, n0, m0),
                                 (qs, ks, vs, lis, lfs))
    h = hs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, D)
    return _mlstm_out(params, cfg, x, h, z), (C, n, m)


def mlstm_decode(params, cfg, x, state) -> Tuple[jnp.ndarray, Tuple]:
    """x: (B,1,d); state = (C (B,H,D,D), n (B,H,D), m (B,H), conv (B,w-1,ed))."""
    xc, H = cfg.xlstm, cfg.n_heads
    B, _, d = x.shape
    ed = xc.expand * d
    D = ed // H
    C, n, m, conv_state = state
    xn = rms_norm(x, params["ln"], cfg.norm_eps)
    xu = dense(xn, params["up_proj"], lora_pair(params, "up_proj", cfg.lora))
    x_in, z = jnp.split(xu, 2, axis=-1)
    window = jnp.concatenate([conv_state, x_in], axis=1)
    conv = jnp.einsum("bwe,we->be", window.astype(jnp.float32),
                      params["conv_w"].astype(jnp.float32))
    x_c = jax.nn.silu(conv + params["conv_b"].astype(jnp.float32)
                      )[:, None, :].astype(x.dtype)
    q = dense(x_c, params["wq"], lora_pair(params, "wq", cfg.lora))
    k = dense(x_c, params["wk"], lora_pair(params, "wk", cfg.lora))
    v = dense(x_in, params["wv"], lora_pair(params, "wv", cfg.lora))
    q = q.reshape(B, H, D).astype(jnp.float32)
    k = k.reshape(B, H, D).astype(jnp.float32) * (D ** -0.5)
    v = v.reshape(B, H, D).astype(jnp.float32)
    gif = x_c[:, 0].astype(jnp.float32) @ params["w_if"] + params["b_if"]
    li, lf = gif[..., :H], jax.nn.log_sigmoid(gif[..., H:])
    m_new = jnp.maximum(lf + m, li)
    fp = jnp.exp(lf + m - m_new)[..., None]
    ip = jnp.exp(li - m_new)[..., None]
    C = fp[..., None] * C + ip[..., None] * (k[..., :, None] * v[..., None, :])
    n = fp * n + ip * k
    num = jnp.einsum("bhdk,bhd->bhk", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)),
                      jnp.exp(-m_new))[..., None]
    h = (num / den)[:, None]                            # (B,1,H,D)
    y = _mlstm_out(params, cfg, x, h, z)
    return y, (C, n, m_new, window[:, 1:, :])


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_params(key, cfg, dtype):
    import jax.random as jr
    from repro.models.common import init_dense
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    ks = jr.split(key, 2)
    b = jnp.zeros((4 * d,)).at[d:2 * d].set(3.0)       # forget-gate bias +3
    return {
        "ln": jnp.ones((d,), dtype),
        "w_gates": init_dense(ks[0], (d, 4 * d), dtype),
        "r_gates": init_dense(ks[1], (H, hd, 4 * hd), jnp.float32, scale=0.5),
        "b_gates": b,
        "gn": jnp.ones((d,), dtype),
    }


def _slstm_step(params, cfg, gx_t, carry):
    """One sLSTM cell step.  gx_t: (B, 4d) f32 input-side gate preacts."""
    H = cfg.n_heads
    c, n, h, m = carry                                  # each (B, d)
    B, d = c.shape
    hd = d // H
    gh = jnp.einsum("bhk,hko->bho", h.reshape(B, H, hd),
                    params["r_gates"])                  # (B,H,4*hd)
    # reorder per-head [i|f|z|o] blocks to match gx's full-d [i|f|z|o] layout
    gh = gh.reshape(B, H, 4, hd).transpose(0, 2, 1, 3).reshape(B, 4 * d)
    g = gx_t + gh
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)
    li = gi
    lf = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(lf + m, li)
    ip = jnp.exp(li - m_new)
    fp = jnp.exp(lf + m - m_new)
    c_new = fp * c + ip * jnp.tanh(gz)
    n_new = fp * n + ip
    h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new)


def _slstm_gx(params, cfg, x):
    xn = rms_norm(x, params["ln"], cfg.norm_eps)
    gx = dense(xn, params["w_gates"], lora_pair(params, "w_gates", cfg.lora))
    # reorder (4d) → per-head blocks:  w_gates emits [i|f|z|o] over full d,
    # matching the recurrent layout because r_gates emits the same split.
    return gx.astype(jnp.float32) + params["b_gates"]


def slstm_train(params, cfg, x) -> Tuple[jnp.ndarray, Tuple]:
    B, S, d = x.shape
    gx = _slstm_gx(params, cfg, x)                      # (B,S,4d)

    def step(carry, t):
        new = _slstm_step(params, cfg, gx[:, t], carry)
        return new, new[2]

    z0 = jnp.zeros((B, d), jnp.float32)
    carry0 = (z0, z0, z0, z0)
    carry, hs = jax.lax.scan(step, carry0, jnp.arange(S))
    h = hs.transpose(1, 0, 2)                           # (B,S,d)
    y = _group_norm(h.astype(x.dtype), params["gn"], cfg.n_heads)
    return x + y, carry


def slstm_decode(params, cfg, x, state) -> Tuple[jnp.ndarray, Tuple]:
    gx = _slstm_gx(params, cfg, x)                      # (B,1,4d)
    carry = _slstm_step(params, cfg, gx[:, 0], state)
    y = _group_norm(carry[2][:, None].astype(x.dtype), params["gn"],
                    cfg.n_heads)
    return x + y, carry
