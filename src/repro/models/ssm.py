"""Mamba (S6 selective scan) mixer — Jamba's recurrent component.

Training uses a chunked selective scan: an outer ``lax.scan`` over sequence
chunks carrying the SSM state, with a parallel ``associative_scan`` inside
each chunk — bounding live memory to O(chunk · ed · N) while keeping the
HLO compact.  Decode is a single recurrent update (O(1) per token), which is
what makes jamba/long_500k legal (DESIGN.md §6.7).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense, lora_pair, rms_norm

SEQ_CHUNK = 128


def mamba_params(key, cfg, dtype):
    import jax.random as jr
    from repro.models.common import init_dense
    mc, d = cfg.mamba, cfg.d_model
    ed = mc.expand * d
    dt_rank = mc.dt_rank or -(-d // 16)
    ks = jr.split(key, 6)
    dt_init = jnp.log(jnp.expm1(
        jnp.exp(jax.random.uniform(ks[5], (ed,), jnp.float32) *
                (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))))
    return {
        "ln": jnp.ones((d,), dtype),
        "in_proj": init_dense(ks[0], (d, 2 * ed), dtype),
        "conv_w": init_dense(ks[1], (mc.d_conv, ed), dtype, scale=0.5),
        "conv_b": jnp.zeros((ed,), dtype),
        "x_proj": init_dense(ks[2], (ed, dt_rank + 2 * mc.d_state), dtype),
        "dt_w": init_dense(ks[3], (dt_rank, ed), dtype),
        "dt_b": dt_init.astype(jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, mc.d_state + 1, dtype=jnp.float32), (ed, mc.d_state))),
        "D": jnp.ones((ed,), jnp.float32),
        "out_proj": init_dense(ks[4], (ed, d), dtype,
                               scale=0.5 / (d ** 0.5 * cfg.n_layers ** 0.5)),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv.  x: (B,S,ed); w: (width, ed)."""
    width, ed = w.shape
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32), w.astype(jnp.float32)[:, None, :],
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=ed)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _ssm_inputs(params, cfg, x_c):
    """dt (B,S,ed) f32, B/C (B,S,N) f32, A (ed,N) f32."""
    mc = cfg.mamba
    dt_rank = mc.dt_rank or -(-cfg.d_model // 16)
    xdbc = dense(x_c, params["x_proj"]).astype(jnp.float32)
    dt_in, Bm, Cm = jnp.split(xdbc, [dt_rank, dt_rank + mc.d_state], axis=-1)
    dt = jax.nn.softplus(
        dt_in @ params["dt_w"].astype(jnp.float32) + params["dt_b"])
    A = -jnp.exp(params["A_log"])
    return dt, Bm, Cm, A


def mamba_train(params, cfg, x, *, seq_chunk: int = SEQ_CHUNK
                ) -> Tuple[jnp.ndarray, Tuple]:
    """x: (B,S,d).  Returns (y, (ssm_state, conv_state)) for prefill reuse."""
    mc = cfg.mamba
    B, S, d = x.shape
    ed, N = mc.expand * d, mc.d_state
    xn = rms_norm(x, params["ln"], cfg.norm_eps)
    xu = dense(xn, params["in_proj"], lora_pair(params, "in_proj", cfg.lora))
    x_in, z = jnp.split(xu, 2, axis=-1)
    x_c = jax.nn.silu(_causal_conv(x_in, params["conv_w"], params["conv_b"]))
    dt, Bm, Cm, A = _ssm_inputs(params, cfg, x_c)

    cs = min(seq_chunk, S)
    assert S % cs == 0
    nchunks = S // cs

    def chunk_body(h, idx):
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, idx * cs, cs, axis=1)
        dt_c, B_c, C_c, x_cc = sl(dt), sl(Bm), sl(Cm), sl(x_c)
        da = jnp.exp(dt_c[..., None] * A)                     # (B,cs,ed,N)
        db = (dt_c * x_cc.astype(jnp.float32))[..., None] * B_c[:, :, None, :]

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2

        a_cum, b_cum = jax.lax.associative_scan(combine, (da, db), axis=1)
        h_t = a_cum * h[:, None] + b_cum                      # (B,cs,ed,N)
        y_c = jnp.einsum("bsen,bsn->bse", h_t, C_c)
        y_c = y_c + params["D"] * x_cc.astype(jnp.float32)
        return h_t[:, -1], y_c

    h0 = jnp.zeros((B, ed, N), jnp.float32)
    h_last, ys = jax.lax.scan(chunk_body, h0, jnp.arange(nchunks))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, ed)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = dense(y, params["out_proj"], lora_pair(params, "out_proj", cfg.lora))
    conv_state = x_in[:, S - (mc.d_conv - 1):, :]             # (B, w-1, ed)
    return x + out, (h_last, conv_state)


def mamba_decode(params, cfg, x, ssm_state, conv_state
                 ) -> Tuple[jnp.ndarray, Tuple]:
    """One-token recurrent step.  x: (B,1,d); ssm_state: (B,ed,N) f32;
    conv_state: (B, d_conv-1, ed)."""
    mc = cfg.mamba
    B = x.shape[0]
    xn = rms_norm(x, params["ln"], cfg.norm_eps)
    xu = dense(xn, params["in_proj"], lora_pair(params, "in_proj", cfg.lora))
    x_in, z = jnp.split(xu, 2, axis=-1)                       # (B,1,ed)
    window = jnp.concatenate([conv_state, x_in], axis=1)      # (B,w,ed)
    conv = jnp.einsum("bwe,we->be", window.astype(jnp.float32),
                      params["conv_w"].astype(jnp.float32))
    x_c = jax.nn.silu(conv + params["conv_b"].astype(jnp.float32)
                      )[:, None, :].astype(x.dtype)           # (B,1,ed)
    dt, Bm, Cm, A = _ssm_inputs(params, cfg, x_c)
    da = jnp.exp(dt[:, 0, :, None] * A)                       # (B,ed,N)
    db = (dt[:, 0] * x_c[:, 0].astype(jnp.float32))[..., None] \
        * Bm[:, 0, None, :]
    h = da * ssm_state + db
    y = jnp.einsum("ben,bn->be", h, Cm[:, 0])
    y = y + params["D"] * x_c[:, 0].astype(jnp.float32)
    y = y[:, None, :].astype(x.dtype) * jax.nn.silu(z)
    out = dense(y, params["out_proj"], lora_pair(params, "out_proj", cfg.lora))
    new_conv_state = window[:, 1:, :]
    return x + out, (h, new_conv_state)
