"""Shared model utilities: norms, rotary embeddings, init, LoRA dense."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(dt)


def init_dense(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def dense(x: jnp.ndarray, w: jnp.ndarray,
          lora: Optional[Tuple[jnp.ndarray, jnp.ndarray, float]] = None
          ) -> jnp.ndarray:
    """y = x @ w  (+ LoRA path  scale * (x @ A) @ B  in f32 adapters).

    ``w`` may be bf16 (frozen base); LoRA adapters are f32 and the adapter
    path is computed in the activation dtype.
    """
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if lora is not None:
        a, b, scale = lora
        ax = jnp.einsum("...d,dr->...r", x, a.astype(x.dtype))
        y = y + scale * jnp.einsum("...r,rf->...f", ax, b.astype(x.dtype))
    return y


def weight(params: dict, name: str) -> jnp.ndarray:
    """Resolve a (possibly QLoRA int4-quantized) base weight.

    Quantized layers store ``{name}__q`` (packed uint8 nibbles) and
    ``{name}__s`` (blockwise scales) instead of ``name`` — 4× smaller in
    HBM *and on the wire*: the FSDP all-gather moves the packed form and
    dequantization happens after the collective, per use (the QLoRA
    deployment mode of the paper, realized as collective compression).
    On TPU the fused dequant-matmul is ``repro.kernels.int4_matmul``.
    """
    w = params.get(name)
    if w is not None:
        return w
    from repro.distributed.sharding import constrain, packed_gather_spec
    from repro.peft.lora import dequantize
    # force the FSDP gather in the packed domain (uint8 on the wire);
    # the rule name may carry a cross-attention 'x' prefix
    rule = name[1:] if name.startswith("x") else name
    q = constrain(params[f"{name}__q"], packed_gather_spec(rule))
    s = constrain(params[f"{name}__s"], packed_gather_spec(rule))
    return dequantize(q, s)


def lora_pair(params: dict, name: str, lora_cfg) -> Optional[Tuple]:
    """Fetch (A, B, scale) for target ``name`` if adapters exist."""
    a = params.get(f"{name}_lora_a")
    if a is None:
        return None
    b = params[f"{name}_lora_b"]
    return (a, b, lora_cfg.alpha / lora_cfg.rank)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE / sectioned M-RoPE realization)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float,
               sections: Tuple[int, ...] = ()) -> jnp.ndarray:
    """Per-pair inverse frequencies, shape (head_dim//2,).

    For M-RoPE (qwen2-vl) the rotary dims are partitioned into
    temporal/height/width sections; with scalar (text) positions all three
    share the position index, so the realization reduces to concatenated
    per-section frequency ladders (documented in DESIGN.md).
    """
    half = head_dim // 2
    if not sections:
        return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) * 2 / head_dim))
    freqs = []
    for sec in sections:
        freqs.append(1.0 / (theta ** (jnp.arange(sec, dtype=jnp.float32) * 2
                                      / (2 * sec))))
    out = jnp.concatenate(freqs)
    assert out.shape[0] == half, (sections, head_dim)
    return out


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               freqs: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, D) or (..., S, D); positions: (..., S)."""
    angles = positions.astype(jnp.float32)[..., None] * freqs   # (..., S, D/2)
    if x.ndim == angles.ndim + 1:                               # head axis
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jnp.ndarray) -> jnp.ndarray:
    """Input is the fused (gate‖up) projection; returns silu(gate)*up."""
    gate, up = jnp.split(x, 2, axis=-1)
    return jax.nn.silu(gate) * up


def soft_cap(x, cap: float):
    return cap * jnp.tanh(x / cap)
