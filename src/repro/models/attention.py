"""Attention mixers: GQA (full / sliding-window) and MLA.

Training/prefill uses a chunked online-softmax ("flash") implementation in
pure jnp — HLO-compact (double lax.scan) and O(chunk²) memory — so 32k-token
prefill lowers within VMEM/HBM budgets.  The Pallas kernel in
``repro.kernels.flash_attention`` is the TPU fast path; this module is the
lowering-friendly default used by the dry-run (see DESIGN.md §5).

Decode uses a single-dot path over the (possibly seq-sharded) KV cache —
GSPMD turns the softmax normalizers into small all-reduces (flash-decode
equivalent).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (apply_rope, dense, lora_pair, rms_norm,
                                 rope_freqs, weight)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# chunked flash attention (training / prefill)
# ---------------------------------------------------------------------------
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    q_offset: int = 0,
                    q_chunk: int = 512, k_chunk: int = 512,
                    anchor: bool = True) -> jnp.ndarray:
    """q: (B, Sq, H, D); k/v: (B, Sk, KH, Dk/Dv).  GQA via head grouping.

    ``q_offset``: absolute position of q[0] relative to k[0] (for decoder
    tokens attending past a prefix).  ``window`` > 0 enables sliding-window.
    Returns (B, Sq, H, Dv).
    """
    B, Sq, H, D = q.shape
    _, Sk, KH, Dv = v.shape
    G = H // KH
    scale = D ** -0.5

    # largest divisor ≤ requested chunk (encoder lengths like 1500 are not
    # powers of two)
    q_chunk = next(c for c in range(min(q_chunk, Sq), 0, -1) if Sq % c == 0)
    k_chunk = next(c for c in range(min(k_chunk, Sk), 0, -1) if Sk % c == 0)
    nq, nk = Sq // q_chunk, Sk // k_chunk

    qr = (q.reshape(B, nq, q_chunk, KH, G, D)
           .transpose(1, 0, 3, 4, 2, 5))                 # (nq,B,KH,G,qc,D)
    kr = k.reshape(B, nk, k_chunk, KH, D).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(B, nk, k_chunk, KH, Dv).transpose(1, 0, 3, 2, 4)

    # Anchor the loop layout: without explicit constraints the partitioner
    # reshards the grouped-head tensors on EVERY chunk step (≈TB-scale
    # dynamic all-to-all traffic; EXPERIMENTS.md §Perf iteration 1).  Shard
    # heads on 'model' — KH when divisible, else the G (q-groups-per-kv)
    # dim — and batch on ('pod','data').
    from repro.distributed.sharding import (constrain, head_axis_choice,
                                            mesh_axis_size)
    from jax.sharding import PartitionSpec as P
    kh_ax, g_ax = head_axis_choice(KH, G) if anchor else (None, None)
    # neither head dim divisible (e.g. kimi KH=8, G=8 on a 16-way axis):
    # context-parallel fallback — shard the q-chunk dim instead
    qc_ax = None
    if anchor and kh_ax is None and g_ax is None \
            and q_chunk % max(mesh_axis_size("model"), 1) == 0:
        qc_ax = "model"
    _BA = ("pod", "data")
    if anchor:
        qr = constrain(qr, P(None, _BA, kh_ax, g_ax, qc_ax, None))
        kr = constrain(kr, P(None, _BA, kh_ax, None, None))
        vr = constrain(vr, P(None, _BA, kh_ax, None, None))

    q_pos_base = jnp.arange(q_chunk)
    k_pos_base = jnp.arange(k_chunk)

    def q_chunk_body(qi, qc):
        # online softmax over k chunks
        m0 = jnp.full((B, KH, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KH, G, q_chunk), jnp.float32)
        acc0 = jnp.zeros((B, KH, G, q_chunk, Dv), jnp.float32)
        if anchor:
            m0 = constrain(m0, P(_BA, kh_ax, g_ax, qc_ax))
            l0 = constrain(l0, P(_BA, kh_ax, g_ax, qc_ax))
            acc0 = constrain(acc0, P(_BA, kh_ax, g_ax, qc_ax, None))

        def k_chunk_body(carry, kin):
            m, l, acc = carry
            ki, kc, vc = kin
            s = jnp.einsum("bkgqd,bkcd->bkgqc", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            if anchor:
                s = constrain(s, P(_BA, kh_ax, g_ax, qc_ax, None))
            qpos = q_offset + qi * q_chunk + q_pos_base       # (qc,)
            kpos = ki * k_chunk + k_pos_base                  # (kc,)
            mask = jnp.ones((q_chunk, k_chunk), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window:
                mask &= qpos[:, None] - kpos[None, :] < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqc,bkcd->bkgqd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            k_chunk_body, (m0, l0, acc0),
            (jnp.arange(nk), kr, vr))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q.dtype)                       # (B,KH,G,qc,Dv)

    outs = jax.lax.map(lambda args: q_chunk_body(*args),
                       (jnp.arange(nq), qr))             # (nq,B,KH,G,qc,Dv)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, Dv)
    return out


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, pos: jnp.ndarray, *,
                     window: int = 0) -> jnp.ndarray:
    """Single-token attention.  q: (B,1,H,D); caches: (B,S,KH,D[v]).

    ``pos``: scalar int32, index of the *current* token (entries > pos are
    masked).  For rolling-window caches S == window and entries are valid by
    construction.  Returns (B,1,H,Dv).
    """
    B, _, H, D = q.shape
    _, S, KH, Dv = v_cache.shape
    G = H // KH
    qr = q.reshape(B, KH, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    idx = jnp.arange(S)
    valid = idx <= pos
    if window:
        valid &= idx > pos - window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------
def gqa_params(key, cfg, dtype, cross: bool = False):
    import jax.random as jr
    from repro.models.common import init_dense
    H, KH, D, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    ks = jr.split(key, 4)
    pre = "x" if cross else ""
    return {
        f"{pre}ln": jnp.ones((d,), dtype),
        f"{pre}wq": init_dense(ks[0], (d, H * D), dtype),
        f"{pre}wkv": init_dense(ks[1], (d, 2 * KH * D), dtype),
        f"{pre}wo": init_dense(ks[2], (H * D, d), dtype,
                               scale=0.5 / (d ** 0.5 * cfg.n_layers ** 0.5)),
    }


def gqa_qkv(params, cfg, x, positions, *, rope: bool = True, pre: str = ""):
    H, KH, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    B, S, _ = x.shape
    xn = rms_norm(x, params[f"{pre}ln"], cfg.norm_eps)
    q = dense(xn, weight(params, f"{pre}wq"),
              lora_pair(params, f"{pre}wq", cfg.lora)).reshape(B, S, H, D)
    kv = dense(xn, weight(params, f"{pre}wkv"),
               lora_pair(params, f"{pre}wkv", cfg.lora)).reshape(B, S, 2, KH, D)
    k, v = kv[:, :, 0], kv[:, :, 1]
    if rope:
        freqs = rope_freqs(D, cfg.rope_theta, cfg.mrope_sections)
        q = apply_rope(q, positions, freqs)
        k = apply_rope(k, positions, freqs)
    return xn, q, k, v


def gqa_out(params, cfg, x, attn_out, pre: str = ""):
    B, S, H, D = attn_out.shape
    o = dense(attn_out.reshape(B, S, H * D), weight(params, f"{pre}wo"),
              lora_pair(params, f"{pre}wo", cfg.lora))
    return x + o


def attn_train(params, cfg, x, positions, *, causal=True, window=None,
               anchor=True):
    """Full-sequence GQA layer (train/prefill).  Returns (y, (k, v))."""
    _, q, k, v = gqa_qkv(params, cfg, x, positions)
    w = cfg.sliding_window if window is None else window
    out = flash_attention(q, k, v, causal=causal, window=w, anchor=anchor)
    return gqa_out(params, cfg, x, out), (k, v)


def attn_decode(params, cfg, x, pos, k_cache, v_cache, *, window: int = 0):
    """One-token GQA step.  x: (B,1,d).  Returns (y, (k_cache, v_cache))."""
    positions = pos[None, None] if pos.ndim == 0 else pos
    _, q, k, v = gqa_qkv(params, cfg, x,
                         jnp.broadcast_to(positions, x.shape[:2]))
    S = k_cache.shape[1]
    rolling = bool(window) and S == window
    slot = pos % S if rolling else pos
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, slot, 0, 0))
    if rolling:
        # rolling cache: slots wrap; unwritten slots exist only while
        # pos < S, in which case "idx <= pos" is exactly the written set.
        out = decode_attention(q, k_cache, v_cache,
                               jnp.minimum(pos, S - 1), window=0)
    else:
        out = decode_attention(q, k_cache, v_cache, pos, window=window)
    return gqa_out(params, cfg, x, out), (k_cache, v_cache)


def cross_attn_train(params, cfg, x, enc_kv):
    """Decoder cross-attention over encoder output (k, v)."""
    B, S, _ = x.shape
    xn = rms_norm(x, params["xln"], cfg.norm_eps)
    H, D = cfg.n_heads, cfg.head_dim
    q = dense(xn, weight(params, "xwq"),
              lora_pair(params, "xwq", cfg.lora)).reshape(B, S, H, D)
    k, v = enc_kv
    out = flash_attention(q, k, v, causal=False)
    o = dense(out.reshape(B, S, H * D), weight(params, "xwo"),
              lora_pair(params, "xwo", cfg.lora))
    return x + o


def cross_kv(params, cfg, enc_out):
    """Precompute cross-attention K/V from encoder output (prefill)."""
    B, F, _ = enc_out.shape
    KH, D = cfg.n_kv_heads, cfg.head_dim
    kv = dense(enc_out, weight(params, "xwkv"),
               lora_pair(params, "xwkv", cfg.lora)).reshape(B, F, 2, KH, D)
    return kv[:, :, 0], kv[:, :, 1]


def cross_attn_decode(params, cfg, x, xk, xv):
    B, S, _ = x.shape
    xn = rms_norm(x, params["xln"], cfg.norm_eps)
    H, D = cfg.n_heads, cfg.head_dim
    q = dense(xn, weight(params, "xwq"),
              lora_pair(params, "xwq", cfg.lora)).reshape(B, S, H, D)
    out = decode_attention(q, xk, xv, jnp.asarray(xk.shape[1] - 1))
    o = dense(out.reshape(B, S, H * D), weight(params, "xwo"),
              lora_pair(params, "xwo", cfg.lora))
    return x + o


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V2 / MiniCPM3)
# ---------------------------------------------------------------------------
def mla_params(key, cfg, dtype):
    import jax.random as jr
    from repro.models.common import init_dense
    m, d, H = cfg.mla, cfg.d_model, cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jr.split(key, 6)
    return {
        "ln": jnp.ones((d,), dtype),
        "wq_a": init_dense(ks[0], (d, m.q_lora_rank), dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "wq_b": init_dense(ks[1], (m.q_lora_rank, H * qk_dim), dtype),
        "wkv_a": init_dense(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                            dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wkv_b": init_dense(ks[3], (m.kv_lora_rank,
                                    H * (m.qk_nope_head_dim + m.v_head_dim)),
                            dtype),
        "wo": init_dense(ks[4], (H * m.v_head_dim, d), dtype,
                         scale=0.5 / (d ** 0.5 * cfg.n_layers ** 0.5)),
    }


def _mla_q(params, cfg, xn, positions):
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = xn.shape
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    cq = dense(xn, weight(params, "wq_a"), lora_pair(params, "wq_a", cfg.lora))
    cq = rms_norm(cq, params["q_norm"], cfg.norm_eps)
    q = dense(cq, weight(params, "wq_b"),
              lora_pair(params, "wq_b", cfg.lora)).reshape(B, S, H, qk_dim)
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions,
                        rope_freqs(m.qk_rope_head_dim, cfg.rope_theta))
    return q_nope, q_rope


def _mla_ckv(params, cfg, xn, positions):
    m = cfg.mla
    ckv_full = dense(xn, weight(params, "wkv_a"), lora_pair(params, "wkv_a", cfg.lora))
    c_kv = rms_norm(ckv_full[..., : m.kv_lora_rank], params["kv_norm"],
                    cfg.norm_eps)
    k_rope = apply_rope(ckv_full[..., m.kv_lora_rank:], positions,
                        rope_freqs(m.qk_rope_head_dim, cfg.rope_theta))
    return c_kv, k_rope


def mla_train(params, cfg, x, positions, *, window: int = 0, anchor=True):
    """Full-sequence MLA.  Materializes per-head K/V from the latent (the
    training-time formulation); cache is the compressed (c_kv, k_rope)."""
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = x.shape
    xn = rms_norm(x, params["ln"], cfg.norm_eps)
    q_nope, q_rope = _mla_q(params, cfg, xn, positions)
    c_kv, k_rope = _mla_ckv(params, cfg, xn, positions)
    kv = dense(c_kv, weight(params, "wkv_b"), lora_pair(params, "wkv_b", cfg.lora))
    kv = kv.reshape(B, S, H, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = kv[..., : m.qk_nope_head_dim], kv[..., m.qk_nope_head_dim:]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], axis=-1)
    out = flash_attention(q, k, v, causal=True, window=window,
                          anchor=anchor)
    o = dense(out.reshape(B, S, H * m.v_head_dim), weight(params, "wo"),
              lora_pair(params, "wo", cfg.lora))
    return x + o, (c_kv, k_rope)


def mla_decode(params, cfg, x, pos, ckv_cache, krope_cache, *,
               window: int = 0):
    """Absorbed-matrix MLA decode: attention runs in the latent space, so the
    cache stays compressed — the family's memory contribution."""
    m, H = cfg.mla, cfg.n_heads
    B = x.shape[0]
    xn = rms_norm(x, params["ln"], cfg.norm_eps)
    positions = jnp.broadcast_to(pos[None, None], x.shape[:2])
    q_nope, q_rope = _mla_q(params, cfg, xn, positions)   # (B,1,H,·)
    c_kv, k_rope = _mla_ckv(params, cfg, xn, positions)   # (B,1,r),(B,1,rope)
    ckv_cache = jax.lax.dynamic_update_slice(
        ckv_cache, c_kv.astype(ckv_cache.dtype), (0, pos, 0))
    krope_cache = jax.lax.dynamic_update_slice(
        krope_cache, k_rope.astype(krope_cache.dtype), (0, pos, 0))

    wkv_b = weight(params, "wkv_b").reshape(
        m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim)
    w_uk = wkv_b[..., : m.qk_nope_head_dim]               # (r,H,nope)
    w_uv = wkv_b[..., m.qk_nope_head_dim:]                # (r,H,v)
    # absorb: q' = q_nope @ W_uk^T  -> latent-space query
    q_lat = jnp.einsum("bihn,rhn->bihr", q_nope, w_uk.astype(q_nope.dtype))
    s = (jnp.einsum("bihr,bsr->bhis", q_lat, ckv_cache,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bihn,bsn->bhis", q_rope, krope_cache,
                      preferred_element_type=jnp.float32))
    s = s * ((m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5)
    S = ckv_cache.shape[1]
    idx = jnp.arange(S)
    valid = idx <= pos
    if window:
        valid &= idx > pos - window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhis,bsr->bihr", p.astype(ckv_cache.dtype), ckv_cache)
    out = jnp.einsum("bihr,rhv->bihv", ctx, w_uv.astype(ctx.dtype))
    o = dense(out.reshape(B, 1, H * m.v_head_dim).astype(x.dtype),
              weight(params, "wo"), lora_pair(params, "wo", cfg.lora))
    return x + o, (ckv_cache, krope_cache)
