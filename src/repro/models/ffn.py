"""Feed-forward blocks: SwiGLU MLP and capacity-based top-k MoE.

MoE uses scatter/gather dispatch into per-expert capacity buffers
(drop-on-overflow), which is GSPMD-expressible: experts are sharded along
the 'model' mesh axis (expert parallelism) while tokens are sharded along
'data', so dispatch/combine lower to the all-to-all-equivalent collective
traffic the roofline analysis measures.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense, lora_pair, rms_norm, swiglu, weight


# ---------------------------------------------------------------------------
# dense SwiGLU MLP
# ---------------------------------------------------------------------------
def mlp_params(key, cfg, dtype, d_ff=None):
    import jax.random as jr
    from repro.models.common import init_dense
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    k1, k2 = jr.split(key)
    return {
        "ln2": jnp.ones((d,), dtype),
        "w_in": init_dense(k1, (d, 2 * ff), dtype),
        "w_out": init_dense(k2, (ff, d), dtype,
                            scale=0.5 / (d ** 0.5 * cfg.n_layers ** 0.5)),
    }


def mlp(params, cfg, x):
    xn = rms_norm(x, params["ln2"], cfg.norm_eps)
    h = swiglu(dense(xn, weight(params, "w_in"), lora_pair(params, "w_in", cfg.lora)))
    return x + dense(h, weight(params, "w_out"), lora_pair(params, "w_out", cfg.lora))


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def moe_params(key, cfg, dtype):
    import jax.random as jr
    from repro.models.common import init_dense
    m, d = cfg.moe, cfg.d_model
    ks = jr.split(key, 5)
    p = {
        "ln2": jnp.ones((d,), dtype),
        "router": init_dense(ks[0], (d, m.n_experts), jnp.float32),
        "w_in": init_dense(ks[1], (m.n_experts, d, 2 * m.d_ff), dtype),
        "w_out": init_dense(ks[2], (m.n_experts, m.d_ff, d), dtype,
                            scale=0.5 / (d ** 0.5 * cfg.n_layers ** 0.5)),
    }
    if m.n_shared_experts:
        sff = m.d_ff * m.n_shared_experts
        p["shared_w_in"] = init_dense(ks[3], (d, 2 * sff), dtype)
        p["shared_w_out"] = init_dense(
            ks[4], (sff, d), dtype,
            scale=0.5 / (d ** 0.5 * cfg.n_layers ** 0.5))
    return p


def _capacity(n_tokens: int, m) -> int:
    c = int(math.ceil(m.top_k * n_tokens * m.capacity_factor / m.n_experts))
    return max(8, -(-c // 8) * 8)      # round up to 8


def moe(params, cfg, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y, balance_loss).  x: (B, S, d)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xn = rms_norm(x, params["ln2"], cfg.norm_eps).reshape(T, d)

    logits = jnp.einsum("td,de->te", xn.astype(jnp.float32),
                        params["router"])                       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, m.top_k)       # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], m.n_experts), axis=0)
    router_mean = probs.mean(axis=0)
    balance = m.n_experts * jnp.sum(density * router_mean)

    # position-in-expert via SORT-based ranking — O(T·k) memory.  The
    # (T·k, E) one-hot cumsum this replaces was both the MoE memory hog
    # (50 GB at kimi scale) and a per-step collective storm inside the
    # cumsum loop (EXPERIMENTS.md §Perf, MoE iteration).
    flat_e = expert_ids.reshape(-1)                             # (T*k,)
    TK = flat_e.shape[0]
    # routing metadata is tiny (T·k ints) — replicate it so the sort runs
    # redundantly per device instead of as a distributed bitonic sort
    # (a ×100 collective-op storm under GSPMD; §Perf kimi iteration)
    from repro.distributed.sharding import constrain as _c
    from jax.sharding import PartitionSpec as _P
    flat_e = _c(flat_e, _P(None))
    order = jnp.argsort(flat_e, stable=True)                    # (T*k,)
    order = _c(order, _P(None))
    sorted_e = flat_e[order]
    # first index of each expert's run within the sorted stream
    starts = jnp.searchsorted(sorted_e, jnp.arange(m.n_experts))
    pos_sorted = jnp.arange(TK) - starts[sorted_e]
    pos = jnp.zeros((TK,), jnp.int32).at[order].set(pos_sorted)
    C = _capacity(T, m)
    keep = pos < C

    # dispatch: scatter tokens into (E, C, d) buffers, expert-sharded on
    # 'model' (expert parallelism) — GSPMD lowers the token→owner exchange
    # to all-to-all instead of all-reducing a replicated buffer.
    # (NOTE §Perf: replicating these buffers at small T was tried as a
    # decode optimization and REFUTED — it forces full expert-weight
    # replication, 157 GiB/dev at jamba scale.)
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import constrain
    espec = P("model", None, None)
    buf = jnp.zeros((m.n_experts, C, d), xn.dtype)
    buf = constrain(buf, espec)
    tok_idx = jnp.repeat(jnp.arange(T), m.top_k)
    x_flat = xn[tok_idx] * keep[:, None].astype(xn.dtype)
    safe_pos = jnp.where(keep, pos, C - 1)
    buf = buf.at[flat_e, safe_pos].add(
        jnp.where(keep[:, None], x_flat, 0))
    buf = constrain(buf, espec)

    # expert computation (sharded over 'model' on the E axis)
    h = jnp.einsum("ecd,edf->ecf", buf, weight(params, "w_in").astype(buf.dtype))
    h = constrain(h, espec)
    h = swiglu(h)
    out_buf = jnp.einsum("ecf,efd->ecd", h,
                         weight(params, "w_out").astype(h.dtype))  # (E,C,d)
    out_buf = constrain(out_buf, espec)

    # combine: gather back, weight by gates
    y_k = out_buf[flat_e, safe_pos] * keep[:, None].astype(out_buf.dtype)
    y_k = y_k.reshape(T, m.top_k, d) * gate_vals[..., None].astype(out_buf.dtype)
    y = y_k.sum(axis=1)

    if m.n_shared_experts:
        sh = swiglu(dense(xn, weight(params, "shared_w_in"),
                          lora_pair(params, "shared_w_in", cfg.lora)))
        y = y + dense(sh, weight(params, "shared_w_out"),
                      lora_pair(params, "shared_w_out", cfg.lora))

    return x + y.reshape(B, S, d).astype(x.dtype), balance
