"""Analytic parameter counts (total + active) for 6·N·D roofline terms."""
from __future__ import annotations


def _layer_params(cfg, mixer: str, ffn: str, cross: bool = False) -> tuple:
    """Returns (total, active) params of one layer."""
    d, H, KH, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    tot = 0
    if mixer == "attn":
        tot += d + d * H * D + d * 2 * KH * D + H * D * d
    elif mixer == "mla":
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        tot += (d + d * m.q_lora_rank + m.q_lora_rank
                + m.q_lora_rank * H * qk
                + d * (m.kv_lora_rank + m.qk_rope_head_dim) + m.kv_lora_rank
                + m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
                + H * m.v_head_dim * d)
    elif mixer == "mamba":
        mc = cfg.mamba
        ed = mc.expand * d
        dt_rank = mc.dt_rank or -(-d // 16)
        tot += (d + d * 2 * ed + mc.d_conv * ed + 2 * ed
                + ed * (dt_rank + 2 * mc.d_state) + dt_rank * ed
                + ed * mc.d_state + ed + ed * d)
    elif mixer == "mlstm":
        xc = cfg.xlstm
        ed = xc.expand * d
        tot += (d + d * 2 * ed + xc.conv_width * ed + ed
                + 3 * ed * ed + ed * 2 * H + 2 * H + ed + ed * d)
    elif mixer == "slstm":
        hd = d // H
        tot += d + d * 4 * d + H * hd * 4 * hd + 4 * d + d
    if cross:
        tot += d + d * H * D + d * 2 * KH * D + H * D * d
    act = tot
    if ffn == "mlp":
        ffd = cfg.d_ff
        if not ffd:
            ffd = int(d * (cfg.xlstm.slstm_ffn_factor if cfg.xlstm else 4))
            ffd = -(-ffd // 128) * 128
        w = d + d * 2 * ffd + ffd * d
        tot += w
        act += w
    elif ffn == "moe":
        m = cfg.moe
        expert = d * 2 * m.d_ff + m.d_ff * d
        tot += d + d * m.n_experts + m.n_experts * expert
        act += d + d * m.n_experts + m.top_k * expert
        if m.n_shared_experts:
            sh = (d * 2 * m.d_ff * m.n_shared_experts
                  + m.d_ff * m.n_shared_experts * d)
            tot += sh
            act += sh
    return tot, act


def count_params(cfg) -> int:
    tot = cfg.vocab_size * cfg.d_model                   # embed
    if not cfg.tie_embeddings:
        tot += cfg.d_model * cfg.vocab_size              # lm head
    tot += cfg.d_model                                   # final norm
    for (mixer, ffn) in cfg.pattern:
        t, _ = _layer_params(cfg, mixer, ffn)
        tot += t * cfg.n_groups
    if cfg.encoder_decoder:
        # decoder layers gain cross-attention; encoder stack mirrors pattern
        t, _ = _layer_params(cfg, "attn", "mlp", cross=True)
        t0, _ = _layer_params(cfg, "attn", "mlp")
        tot += (t - t0) * cfg.n_layers                   # cross-attn add-on
        tot += t0 * cfg.n_encoder_layers + cfg.d_model
    if cfg.frontend:
        tot += cfg.d_model * cfg.d_model                 # projector stub
    return int(tot)


def count_active_params(cfg) -> int:
    act = cfg.vocab_size * cfg.d_model
    if not cfg.tie_embeddings:
        act += cfg.d_model * cfg.vocab_size
    act += cfg.d_model
    for (mixer, ffn) in cfg.pattern:
        _, a = _layer_params(cfg, mixer, ffn)
        act += a * cfg.n_groups
    if cfg.encoder_decoder:
        t, _ = _layer_params(cfg, "attn", "mlp", cross=True)
        t0, _ = _layer_params(cfg, "attn", "mlp")
        act += (t - t0) * cfg.n_layers
        act += t0 * cfg.n_encoder_layers + cfg.d_model
    if cfg.frontend:
        act += cfg.d_model * cfg.d_model
    return int(act)
