"""Model assembly: init, forward, train/prefill/serve steps, input specs.

Every model is: embed (+frontend stub prefix) → scan(remat(layer-group))
→ final RMSNorm → (chunked-CE loss | logits).  ``train_step`` is the
paper's technique — LoRA fine-tuning: base weights frozen, adapters + AdamW
trained, with microbatch gradient accumulation so 400B-class configs fit
v5e HBM (DESIGN.md §6.8).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, InputShape
from repro.distributed.sharding import constrain, batch_axes
from repro.models import layers as L
from repro.models.common import dense, init_dense, rms_norm
from repro.optim import adamw
from repro.peft import lora as lora_mod


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(cfg: ModelConfig, key, dtype=None) -> Dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    params: Dict = {
        "embed": init_dense(keys[0], (cfg.vocab_size, cfg.d_model), dtype,
                            scale=0.02),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(keys[1], (cfg.d_model, cfg.vocab_size),
                                       dtype)
    if cfg.frontend:
        params["proj_frontend"] = init_dense(
            keys[2], (cfg.d_model, cfg.d_model), dtype)

    def stack_layers(key, n_groups, mixer, ffn, cross):
        ks = jax.random.split(key, n_groups)
        return jax.vmap(
            lambda k: L.init_layer_params(k, cfg, mixer, ffn, dtype,
                                          cross=cross))(ks)

    gkeys = jax.random.split(keys[3], len(cfg.pattern))
    params["groups"] = tuple(
        stack_layers(gk, cfg.n_groups, mixer, ffn,
                     cross=cfg.encoder_decoder)
        for gk, (mixer, ffn) in zip(gkeys, cfg.pattern))

    if cfg.encoder_decoder:
        ekeys = jax.random.split(keys[4], len(cfg.pattern))
        params["enc_groups"] = tuple(
            stack_layers(ek, cfg.n_encoder_layers // len(cfg.pattern),
                         mixer, ffn, cross=False)
            for ek, (mixer, ffn) in zip(ekeys, cfg.pattern))
        params["enc_final_norm"] = jnp.ones((cfg.d_model,), dtype)
    if cfg.lora.quantize_base:
        # QLoRA: frozen base weights stored (and all-gathered) as packed
        # int4 + scales; dequantized per use (common.weight)
        params = lora_mod.quantize_stacked_groups(params, cfg.lora.targets)
    return params


def init_adapters(cfg: ModelConfig, key, params: Dict) -> Dict:
    """LoRA adapters mirroring the group structure (stacked over groups)."""
    out: Dict = {}

    def stack_adapters(key, group_stack):
        one = jax.tree.map(lambda x: x[0], group_stack)
        n_groups = jax.tree.leaves(group_stack)[0].shape[0]
        ks = jax.random.split(key, n_groups)
        return jax.vmap(
            lambda k: lora_mod.init_layer_adapters(k, cfg, one))(ks)

    for gk in ("groups", "enc_groups"):
        if gk in params:
            keys = jax.random.split(key, len(params[gk]) + 1)
            key = keys[0]
            out[gk] = tuple(stack_adapters(k, g)
                            for k, g in zip(keys[1:], params[gk]))
    return out


def _merge(base_layer: Dict, adapter_layer: Optional[Dict]) -> Dict:
    if not adapter_layer:
        return base_layer
    return {**base_layer, **adapter_layer}


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class FwdOptions:
    window: Optional[int] = None        # override sliding window
    remat: bool = True
    mlstm_chunkwise: bool = False
    collect_cache: bool = False
    causal: bool = True
    seq_parallel: bool = False          # shard residual stream seq on 'model'
    shard_cache: bool = False           # shard collected caches (prefill)
    attn_anchor: bool = True            # anchor attention-loop shardings


_BA = ("pod", "data")


def _shard_cache_tree(tree, batch: int):
    """Prefill-cache sharding: batch over DP axes, long axes over 'model'."""
    def leaf(x):
        if not hasattr(x, "ndim") or x.ndim == 0:
            return x
        spec = [None] * x.ndim
        if batch > 1 and x.shape[0] == batch:
            spec[0] = _BA
        big = [(i, d) for i, d in enumerate(x.shape) if i > 0 and d >= 2048]
        if big:
            i, _ = max(big, key=lambda t: t[1])
            spec[i] = "model"
        return constrain(x, P(*spec))
    return jax.tree.map(leaf, tree)


def _embed_tokens(cfg, params, tokens):
    return jnp.take(params["embed"], tokens, axis=0)


def _run_stack(cfg, groups_base, groups_adp, x, positions, opts: FwdOptions,
               enc_out=None, pattern=None):
    """Scan the layer-group stack.  Returns (x, balance, caches)."""
    pattern = pattern or cfg.pattern

    def group_fn(x, layer_ins):
        # NOTE (§Perf iteration 3, refuted): releasing the seq-sharding at
        # the group entrance ("Megatron seq-parallel") made XLA store the
        # released full-seq copy for the backward pass — peak 26→58 GiB
        # with no collective win.  The carry keeps whatever sharding
        # scan_body constrained; interior layout is left to the
        # partitioner.
        caches, balance = [], jnp.zeros((), jnp.float32)
        for (mixer, ffn), base_l, adp_l in zip(pattern, layer_ins[0],
                                               layer_ins[1]):
            p = _merge(base_l, adp_l)
            enc_kv = None
            if enc_out is not None:
                from repro.models.attention import cross_kv
                enc_kv = cross_kv(p, cfg, enc_out)
            x, cache, bal = L.apply_layer_train(
                cfg, p, x, positions, mixer, ffn,
                causal=opts.causal, window=opts.window,
                mlstm_chunkwise=opts.mlstm_chunkwise, enc_kv=enc_kv,
                anchor=opts.attn_anchor)
            balance = balance + bal
            if opts.collect_cache:
                if enc_kv is not None:
                    cache = (cache, enc_kv)
                if opts.shard_cache:
                    cache = _shard_cache_tree(cache, x.shape[0])
                caches.append(cache)
            else:
                caches.append(None)
        return x, (tuple(caches), balance)

    fn = jax.checkpoint(group_fn) if opts.remat else group_fn

    def scan_body(x, xs):
        x, ys = fn(x, xs)
        if opts.seq_parallel:
            x = constrain(x, P(_BA, "model", None))
        return x, ys

    x, (caches, balances) = jax.lax.scan(
        scan_body, x, (groups_base, groups_adp))
    return x, balances.sum(), caches


def forward(cfg: ModelConfig, params: Dict, adapters: Dict, batch: Dict,
            opts: FwdOptions = FwdOptions()):
    """Returns (hidden (B,S,d) post-norm over *label-bearing* positions,
    balance_loss, caches)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed_tokens(cfg, params, tokens)
    prefix = 0
    enc_out = None

    if cfg.encoder_decoder:
        frames = batch["frontend"]                     # (B, F, d) stub
        e = dense(frames, params["proj_frontend"]) if cfg.frontend else frames
        e_pos = jnp.broadcast_to(jnp.arange(e.shape[1])[None], e.shape[:2])
        eopts = FwdOptions(remat=opts.remat, causal=False)
        e, _, _ = _run_stack(cfg, params["enc_groups"],
                             adapters.get("enc_groups",
                                          _none_like(params["enc_groups"])),
                             e, e_pos, eopts)
        enc_out = rms_norm(e, params["enc_final_norm"], cfg.norm_eps)
    elif cfg.frontend:
        fe = dense(batch["frontend"], params["proj_frontend"])
        x = jnp.concatenate([fe.astype(x.dtype), x], axis=1)
        prefix = fe.shape[1]

    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                 (B, x.shape[1]))
    x, balance, caches = _run_stack(
        cfg, params["groups"],
        adapters.get("groups", _none_like(params["groups"])),
        x, positions, opts, enc_out=enc_out)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if opts.seq_parallel:
        # gather seq before the (vocab-sharded) loss head
        x = constrain(x, P(_BA, None, None))
    if prefix:
        x = x[:, prefix:, :]
    return x, balance, caches


def _none_like(groups):
    # empty adapter dicts: scan-compatible (no leaves), merge-safe
    return tuple({} for _ in groups)


# ---------------------------------------------------------------------------
# chunked cross-entropy
# ---------------------------------------------------------------------------
def chunked_ce(cfg, params, hidden, labels, *, chunk: int = 512):
    """Scan over sequence chunks so (B, chunk, V) logits are the only live
    vocab-sized tensor.  labels < 0 are masked."""
    B, S, d = hidden.shape
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    chunk = min(chunk, S)
    assert S % chunk == 0
    n = S // chunk

    def body(carry, i):
        tot, cnt = carry
        h = jax.lax.dynamic_slice_in_dim(hidden, i * chunk, chunk, axis=1)
        y = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
        logits = jnp.einsum("bsd,dv->bsv", h, head.astype(h.dtype)
                            ).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(y, 0)[..., None], axis=-1)[..., 0]
        mask = (y >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((logz - gold) * mask)
        cnt = cnt + jnp.sum(mask)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        jnp.arange(n))
    return tot / jnp.maximum(cnt, 1.0)


def logits_last(cfg, params, hidden):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    h = hidden[:, -1, :]
    return jnp.einsum("bd,dv->bv", h, head.astype(h.dtype)
                      ).astype(jnp.float32)


# ---------------------------------------------------------------------------
# train step (LoRA fine-tuning — the paper's client-side technique)
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, *, n_microbatches: int = 1,
                    lr: float = 1e-4, opts: FwdOptions = FwdOptions(),
                    loss_chunk: int = 512):
    """Pure ``(params, adapters, opt_state, batch) → (adapters, opt_state,
    metrics)`` LoRA step.

    The returned function is **vmap/scan-composable**: it closes over
    static config only, touches no host state, and every internal op is
    batchable — so the batched LLM engine (``core/batched_llm.py``) can
    run ``lax.scan`` over steps of ``jax.vmap(step, in_axes=(None, 0, 0,
    0))`` with the frozen base replicated and ``(C, …)`` adapter/AdamW
    stacks on the leading client axis.  Keep it that way: no Python side
    effects, no data-dependent Python control flow, no host callbacks.
    """
    def loss_fn(adapters, params, mb):
        hidden, balance, _ = forward(cfg, params, adapters, mb, opts)
        loss = chunked_ce(cfg, params, hidden, mb["labels"],
                          chunk=loss_chunk)
        if cfg.moe:
            loss = loss + cfg.moe.balance_loss_weight * balance
        return loss

    def train_step(params, adapters, opt_state, batch):
        nm = n_microbatches
        ba = ("pod", "data")

        def split(x):
            if x.ndim == 0:
                return x
            b = x.shape[0]
            xm = x.reshape(nm, b // nm, *x.shape[1:])
            return constrain(xm, P(None, ba, *((None,) * (x.ndim - 1))))

        micro = jax.tree.map(split, batch) if nm > 1 else None

        if nm == 1:
            loss, grads = jax.value_and_grad(loss_fn)(adapters, params, batch)
        else:
            def body(carry, i):
                gacc, lacc = carry
                mb = jax.tree.map(
                    lambda x: (jax.lax.dynamic_index_in_dim(
                        x, i, 0, keepdims=False) if x.ndim else x), micro)
                l, g = jax.value_and_grad(loss_fn)(adapters, params, mb)
                return (jax.tree.map(jnp.add, gacc, g), lacc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                              adapters)
            (grads, loss_sum), _ = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), jnp.arange(nm))
            grads = jax.tree.map(lambda g: g / nm, grads)
            loss = loss_sum / nm

        new_adapters, new_opt = adamw.update(grads, opt_state, adapters,
                                             lr=lr)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return new_adapters, new_opt, {"loss": loss, "grad_norm": gnorm}

    return train_step


# One jitted train step per static config, shared across every consumer:
# each LLMClient used to jit its own make_train_step closure, so C
# federated clients paid C identical compiles of the same program.
_TRAIN_STEP_CACHE: dict = {}


def get_train_step(cfg: ModelConfig, *, n_microbatches: int = 1,
                   lr: float = 1e-4, opts: FwdOptions = FwdOptions(),
                   loss_chunk: int = 512):
    """Module-cached ``jax.jit(make_train_step(...))``.

    Keyed by the full static configuration (``ModelConfig`` and
    ``FwdOptions`` are frozen dataclasses, hence hashable), so instances
    with the same config share one compilation; jax's own cache then
    specializes per input shape as usual.
    """
    key = (cfg, int(n_microbatches), float(lr), opts, int(loss_chunk))
    if key not in _TRAIN_STEP_CACHE:
        _TRAIN_STEP_CACHE[key] = jax.jit(make_train_step(
            cfg, n_microbatches=n_microbatches, lr=lr, opts=opts,
            loss_chunk=loss_chunk))
    return _TRAIN_STEP_CACHE[key]


# ---------------------------------------------------------------------------
# prefill / serve
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig, opts: FwdOptions = FwdOptions(
        remat=False, collect_cache=True)):
    def prefill(params, adapters, batch):
        hidden, _, caches = forward(cfg, params, adapters, batch, opts)
        return logits_last(cfg, params, hidden), caches
    return prefill


def init_cache(cfg: ModelConfig, batch: int, seq: int, *,
               window: int = 0, dtype=jnp.bfloat16):
    """Decode caches, stacked (n_groups, ...) per pattern position."""
    def one(mixer):
        s = seq
        if mixer in ("attn", "mla") and window:
            s = min(seq, window)
        base = L.cache_struct(cfg, mixer, batch, s, dtype)
        return jax.tree.map(
            lambda x: jnp.zeros((cfg.n_groups,) + x.shape, x.dtype), base)

    caches = tuple(one(mixer) for (mixer, _) in cfg.pattern)
    if cfg.encoder_decoder:
        F = cfg.n_frontend_tokens
        xkv = jnp.zeros((cfg.n_groups, batch, F, cfg.n_kv_heads,
                         cfg.head_dim), dtype)
        caches = (caches, tuple((jnp.copy(xkv), jnp.copy(xkv))
                                for _ in cfg.pattern))
    return caches


def make_serve_step(cfg: ModelConfig, *, window: int = 0):
    """One-token decode: (params, adapters, cache, token (B,1), pos) →
    (logits (B,V), cache)."""
    def serve(params, adapters, cache, token, pos):
        x = _embed_tokens(cfg, params, token)
        self_caches = cache[0] if cfg.encoder_decoder else cache
        cross = cache[1] if cfg.encoder_decoder else None

        adp = adapters.get("groups", _none_like(params["groups"]))
        has_cross = cfg.encoder_decoder

        def group_fn(carry, xs):
            x = carry
            if has_cross:
                base_g, adp_g, cache_g, cross_g = xs
            else:
                base_g, adp_g, cache_g = xs
                cross_g = None
            new_caches = []
            for idx, (mixer, ffn) in enumerate(cfg.pattern):
                p = _merge(base_g[idx], adp_g[idx])
                w = window if mixer in ("attn", "mla") else 0
                ck = cross_g[idx] if cross_g is not None else None
                x, nc = L.apply_layer_decode(
                    cfg, p, x, pos, cache_g[idx], mixer, ffn,
                    window=w, cross_kv=ck)
                new_caches.append(nc)
            return x, tuple(new_caches)

        xs = ((params["groups"], adp, self_caches, cross) if has_cross
              else (params["groups"], adp, self_caches))
        x, new_self = jax.lax.scan(group_fn, x, xs)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = logits_last(cfg, params, x)
        new_cache = ((new_self, cross) if cfg.encoder_decoder else new_self)
        return logits, new_cache

    return serve


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no allocation)
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: InputShape, *,
                window: int = 0) -> Dict:
    """Abstract inputs for lower()/compile() dry-runs."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
        if cfg.frontend:
            batch["frontend"] = sds((B, cfg.n_frontend_tokens, cfg.d_model),
                                    jnp.bfloat16)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": sds((B, S), i32)}
        if cfg.frontend:
            batch["frontend"] = sds((B, cfg.n_frontend_tokens, cfg.d_model),
                                    jnp.bfloat16)
        return batch
    if shape.kind == "decode":
        cache = jax.eval_shape(
            lambda: init_cache(cfg, B, S, window=window))
        return {"token": sds((B, 1), i32), "pos": sds((), i32),
                "cache": cache}
    raise ValueError(shape.kind)
