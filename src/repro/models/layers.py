"""Layer-group construction and application.

A model is ``cfg.pattern`` applied ``cfg.n_groups`` times; parameters are
stacked along a leading group axis and applied under ``lax.scan`` (+remat),
keeping the HLO one-pattern-period big regardless of depth.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import ssm, xlstm

MIXERS = ("attn", "mla", "mamba", "mlstm", "slstm")
FFNS = ("mlp", "moe", "none")


def init_layer_params(key, cfg, mixer: str, ffn: str, dtype,
                      cross: bool = False) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {}
    if mixer == "attn":
        p.update(attn.gqa_params(k1, cfg, dtype))
    elif mixer == "mla":
        p.update(attn.mla_params(k1, cfg, dtype))
    elif mixer == "mamba":
        p.update(ssm.mamba_params(k1, cfg, dtype))
    elif mixer == "mlstm":
        p.update(xlstm.mlstm_params(k1, cfg, dtype))
    elif mixer == "slstm":
        p.update(xlstm.slstm_params(k1, cfg, dtype))
    else:
        raise ValueError(mixer)
    if cross:
        p.update(attn.gqa_params(k3, cfg, dtype, cross=True))
    if ffn == "mlp":
        ffd = cfg.d_ff
        if ffd == 0:  # xlstm sLSTM post-FFN factor (128-aligned)
            ffd = int(cfg.d_model * cfg.xlstm.slstm_ffn_factor)
            ffd = -(-ffd // 128) * 128
        p.update(ffn_mod.mlp_params(k2, cfg, dtype, d_ff=ffd))
    elif ffn == "moe":
        p.update(ffn_mod.moe_params(k2, cfg, dtype))
    elif ffn != "none":
        raise ValueError(ffn)
    return p


def apply_layer_train(cfg, p: Dict, x, positions, mixer: str, ffn: str, *,
                      causal: bool = True, window=None, enc_kv=None,
                      mlstm_chunkwise: bool = False, anchor: bool = True):
    """Full-sequence layer.  Returns (x, cache, balance_loss)."""
    balance = jnp.zeros((), jnp.float32)
    if mixer == "attn":
        x, cache = attn.attn_train(p, cfg, x, positions, causal=causal,
                                   window=window, anchor=anchor)
    elif mixer == "mla":
        x, cache = attn.mla_train(p, cfg, x, positions,
                                  window=window or 0, anchor=anchor)
    elif mixer == "mamba":
        x, cache = ssm.mamba_train(p, cfg, x)
    elif mixer == "mlstm":
        fn = (xlstm.mlstm_train_chunkwise if mlstm_chunkwise
              else xlstm.mlstm_train)
        x, cache = fn(p, cfg, x)
    elif mixer == "slstm":
        x, cache = xlstm.slstm_train(p, cfg, x)
    else:
        raise ValueError(mixer)
    if enc_kv is not None:
        x = attn.cross_attn_train(p, cfg, x, enc_kv)
    if ffn == "mlp":
        x = ffn_mod.mlp(p, cfg, x)
    elif ffn == "moe":
        x, balance = ffn_mod.moe(p, cfg, x)
    return x, cache, balance


def apply_layer_decode(cfg, p: Dict, x, pos, cache, mixer: str, ffn: str, *,
                       window: int = 0, cross_kv=None):
    """One-token layer step.  Returns (x, new_cache)."""
    if mixer == "attn":
        x, cache = attn.attn_decode(p, cfg, x, pos, *cache, window=window)
    elif mixer == "mla":
        x, cache = attn.mla_decode(p, cfg, x, pos, *cache, window=window)
    elif mixer == "mamba":
        x, cache = ssm.mamba_decode(p, cfg, x, *cache)
    elif mixer == "mlstm":
        x, cache = xlstm.mlstm_decode(p, cfg, x, cache)
    elif mixer == "slstm":
        x, cache = xlstm.slstm_decode(p, cfg, x, cache)
    else:
        raise ValueError(mixer)
    if cross_kv is not None:
        x = attn.cross_attn_decode(p, cfg, x, *cross_kv)
    if ffn == "mlp":
        x = ffn_mod.mlp(p, cfg, x)
    elif ffn == "moe":
        x, _ = ffn_mod.moe(p, cfg, x)
    return x, cache


def cache_struct(cfg, mixer: str, batch: int, seq: int, dtype=jnp.bfloat16):
    """Shapes of one layer's decode cache (no leading group axis)."""
    d, H, KH, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if mixer == "attn":
        return (jnp.zeros((batch, seq, KH, D), dtype),
                jnp.zeros((batch, seq, KH, D), dtype))
    if mixer == "mla":
        m = cfg.mla
        return (jnp.zeros((batch, seq, m.kv_lora_rank), dtype),
                jnp.zeros((batch, seq, m.qk_rope_head_dim), dtype))
    if mixer == "mamba":
        mc = cfg.mamba
        ed = mc.expand * d
        return (jnp.zeros((batch, ed, mc.d_state), jnp.float32),
                jnp.zeros((batch, mc.d_conv - 1, ed), dtype))
    if mixer == "mlstm":
        xc = cfg.xlstm
        ed = xc.expand * d
        hd = ed // H
        return (jnp.zeros((batch, H, hd, hd), jnp.float32),
                jnp.zeros((batch, H, hd), jnp.float32),
                jnp.zeros((batch, H), jnp.float32),
                jnp.zeros((batch, xc.conv_width - 1, ed), dtype))
    if mixer == "slstm":
        z = jnp.zeros((batch, d), jnp.float32)
        return (z, z, z, z)
    raise ValueError(mixer)
