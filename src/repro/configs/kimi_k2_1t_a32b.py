"""kimi-k2-1t-a32b [moe] — trillion-param MoE (DeepSeek-V3 family).

61L d_model=7168 64H (GQA kv=8) d_ff=2048/expert vocab=163840,
MoE 384e top-8 + 1 shared expert.  [arXiv:2501.kimi2]
(Simplification noted in DESIGN.md: first-dense-layer of DSv3 folded into
the uniform MoE pattern for scan homogeneity.)
"""
from repro.configs.base import ModelConfig, MoEConfig, LoRAConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    source="arXiv:2501.kimi2",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=2048,
    vocab_size=163840,
    pattern=(("attn", "moe"),),
    moe=MoEConfig(n_experts=384, top_k=8, d_ff=2048, n_shared_experts=1),
    rope_theta=50000.0,
    lora=LoRAConfig(rank=16, alpha=32.0),
    supports_long_decode=True,    # SWA variant for long_500k (beyond-paper)
    long_decode_window=8192,
)
