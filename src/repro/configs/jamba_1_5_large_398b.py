"""jamba-1.5-large-398b [hybrid] — Mamba + attention 1:7 interleave, MoE.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Period-8 pattern with one attention layer per period (1:7) and MoE every
other layer (Jamba paper layout).  [arXiv:2403.19887]
"""
from repro.configs.base import ModelConfig, MoEConfig, MambaConfig, LoRAConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    source="arXiv:2403.19887",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    pattern=(
        ("mamba", "mlp"), ("mamba", "moe"),
        ("mamba", "mlp"), ("mamba", "moe"),
        ("attn",  "mlp"), ("mamba", "moe"),
        ("mamba", "mlp"), ("mamba", "moe"),
    ),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff=24576),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    rope_theta=10000.0,
    lora=LoRAConfig(rank=16, alpha=32.0),
    supports_long_decode=True,    # Mamba state + sparse attention layers
    long_decode_window=0,         # attention layers keep full cache (9 layers)
)
