"""minicpm3-4b [dense] — MLA (multi-head latent attention).

62L d_model=2560 40H (kv=40 — MLA shares a compressed latent) d_ff=6400
vocab=73448.  [hf:openbmb/MiniCPM3-4B]
"""
from repro.configs.base import ModelConfig, MLAConfig, LoRAConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    arch_type="dense",
    source="hf:openbmb/MiniCPM3-4B",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=64,
    d_ff=6400,
    vocab_size=73448,
    pattern=(("mla", "mlp"),),
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    rope_theta=10000.0,
    lora=LoRAConfig(rank=16, alpha=32.0,
                    targets=("wq_a", "wq_b", "wkv_a", "wkv_b", "wo",
                             "w_in", "w_out")),
    supports_long_decode=True,    # SWA variant for long_500k (beyond-paper)
    long_decode_window=8192,
)
