"""llama4-maverick-400b-a17b [moe] — Llama-4 Maverick-class MoE.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1,
interleaved dense/MoE layers + shared expert (early-fusion family).
[hf:meta-llama/Llama-4-Scout-17B-16E]
"""
from repro.configs.base import ModelConfig, MoEConfig, LoRAConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    # Maverick interleaves dense and MoE layers 1:1.
    pattern=(("attn", "mlp"), ("attn", "moe")),
    moe=MoEConfig(n_experts=128, top_k=1, d_ff=8192, n_shared_experts=1),
    rope_theta=500000.0,
    lora=LoRAConfig(rank=16, alpha=32.0),
    supports_long_decode=True,       # chunked-attention family; SWA variant
    long_decode_window=8192,
)
