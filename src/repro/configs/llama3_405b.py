"""llama3-405b [dense] — GQA, 128k vocab.

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
[arXiv:2407.21783]
"""
from repro.configs.base import ModelConfig, LoRAConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    arch_type="dense",
    source="arXiv:2407.21783",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    pattern=(("attn", "mlp"),),
    rope_theta=500000.0,
    lora=LoRAConfig(rank=16, alpha=32.0),
    supports_long_decode=True,    # SWA variant for long_500k (beyond-paper)
    long_decode_window=8192,
)
