"""stablelm-3b [dense] — MHA dense.

32L d_model=2560 32H (kv=32) d_ff=6912 vocab=50304.
[hf:stabilityai/stablelm-2-1_6b]
"""
from repro.configs.base import ModelConfig, LoRAConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    arch_type="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50304,
    pattern=(("attn", "mlp"),),
    rope_theta=10000.0,
    lora=LoRAConfig(rank=16, alpha=32.0),
    supports_long_decode=True,    # SWA variant for long_500k (beyond-paper)
    long_decode_window=8192,
)
