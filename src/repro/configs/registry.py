"""Architecture registry: ``get(name)`` resolves --arch ids."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, InputShape, INPUT_SHAPES, reduced
from repro.configs import paper_models

_ASSIGNED = {
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b_a17b",
    "qwen2-vl-72b":              "repro.configs.qwen2_vl_72b",
    "whisper-large-v3":          "repro.configs.whisper_large_v3",
    "xlstm-125m":                "repro.configs.xlstm_125m",
    "minicpm3-4b":               "repro.configs.minicpm3_4b",
    "kimi-k2-1t-a32b":           "repro.configs.kimi_k2_1t_a32b",
    "starcoder2-7b":             "repro.configs.starcoder2_7b",
    "llama3-405b":               "repro.configs.llama3_405b",
    "stablelm-3b":               "repro.configs.stablelm_3b",
    "jamba-1.5-large-398b":      "repro.configs.jamba_1_5_large_398b",
}

_PAPER = {
    "llama3.2-1b": paper_models.LLAMA32_1B,
    "gpt2": paper_models.GPT2,
    "deepseek-llm-7b-base": paper_models.DEEPSEEK_7B,
    "tiny-llm": paper_models.TINY_LLM,
}


def assigned_names() -> List[str]:
    return list(_ASSIGNED)


def all_names() -> List[str]:
    return list(_ASSIGNED) + list(_PAPER)


def get(name: str) -> ModelConfig:
    if name in _ASSIGNED:
        return importlib.import_module(_ASSIGNED[name]).CONFIG
    if name in _PAPER:
        return _PAPER[name]
    if name.endswith("-smoke"):
        return reduced(get(name[: -len("-smoke")]))
    raise KeyError(f"unknown arch {name!r}; known: {all_names()}")


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


def pairs(include_skipped: bool = False):
    """All (arch, shape) dry-run pairs; long_500k skipped only for
    full-attention enc-dec (whisper) per DESIGN.md."""
    out = []
    for a in assigned_names():
        cfg = get(a)
        for s in INPUT_SHAPES:
            if s == "long_500k" and not cfg.supports_long_decode:
                if include_skipped:
                    out.append((a, s, "SKIP"))
                continue
            out.append((a, s, "RUN") if include_skipped else (a, s))
    return out
