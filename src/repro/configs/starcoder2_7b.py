"""starcoder2-7b [dense] — GQA + RoPE + native sliding window (4096).

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.  [arXiv:2402.19173]
"""
from repro.configs.base import ModelConfig, LoRAConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    arch_type="dense",
    source="arXiv:2402.19173",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    pattern=(("attn", "mlp"),),
    rope_theta=100000.0,
    sliding_window=4096,          # native SWA → legitimate long_500k
    lora=LoRAConfig(rank=16, alpha=32.0),
    supports_long_decode=True,
    long_decode_window=4096,
)
