"""Model / run configuration system.

Every assigned architecture gets a module in this package exporting
``CONFIG: ModelConfig`` built from the exact numbers in the assignment
table (source cited in the module docstring).  ``registry.get(name)``
resolves ids; ``reduced(cfg)`` derives the CPU smoke-test variant.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------
# A model is a repeating *pattern* of (mixer, ffn) pairs scanned n_groups
# times:  n_layers == len(pattern) * n_groups.
#   mixer ∈ {"attn", "mla", "mamba", "mlstm", "slstm"}
#   ffn   ∈ {"mlp", "moe", "none"}
LayerSpec = Tuple[str, str]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden dim
    n_shared_experts: int = 0      # DeepSeek/Kimi-style always-on experts
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    balance_loss_weight: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None  # default ceil(d_model/16)


@dataclass(frozen=True)
class XLSTMConfig:
    expand: int = 2                # mLSTM inner expansion
    slstm_ffn_factor: float = 4 / 3
    conv_width: int = 4


@dataclass(frozen=True)
class LoRAConfig:
    rank: int = 16
    alpha: float = 32.0
    dropout: float = 0.05
    # which weight families receive adapters
    targets: Tuple[str, ...] = ("wq", "wkv", "wo", "w_in", "w_out")
    quantize_base: bool = False    # QLoRA: int4 base weights


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense|moe|ssm|hybrid|vlm|audio
    source: str                    # citation from assignment table
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    pattern: Tuple[LayerSpec, ...] = (("attn", "mlp"),)
    # attention
    rope_theta: float = 500000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl sectioned rotary
    sliding_window: int = 0                # 0 = full attention
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    lora: LoRAConfig = field(default_factory=LoRAConfig)
    # enc-dec (whisper)
    encoder_decoder: bool = False
    n_encoder_layers: int = 0
    n_frontend_tokens: int = 0     # frames/patches emitted by the stub frontend
    frontend: str = ""             # ""|"audio"|"vision"
    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # long-context policy
    supports_long_decode: bool = False     # sub-quadratic decode path exists
    long_decode_window: int = 8192         # SWA window used for long_500k

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern period {len(self.pattern)}")

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for 6ND roofline."""
        from repro.models.counting import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.counting import count_active_params
        return count_active_params(self)


# ---------------------------------------------------------------------------
# Input shapes (assignment table)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


def reduced(cfg: ModelConfig, *, d_model: int = 256, n_groups: int = 1,
            vocab: int = 512) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (≤2 layers eff.,
    d_model ≤ 512, ≤4 experts)."""
    period = len(cfg.pattern)
    n_heads = max(2, min(4, cfg.n_heads))
    n_kv = 1 if cfg.n_kv_heads < cfg.n_heads else n_heads
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=period * n_groups,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=d_model // n_heads,
        d_ff=d_model * 2 if cfg.d_ff else 0,
        vocab_size=vocab,
        n_encoder_layers=(period * n_groups) if cfg.encoder_decoder else 0,
        n_frontend_tokens=16 if cfg.frontend else 0,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        long_decode_window=64,
    )
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2),
            d_ff=d_model * 2,
            n_shared_experts=min(cfg.moe.n_shared_experts, 1))
    if cfg.mla:
        kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                              qk_nope_head_dim=32, qk_rope_head_dim=16,
                              v_head_dim=32)
    if cfg.lora:
        kw["lora"] = dataclasses.replace(cfg.lora, rank=4)
    if cfg.mrope_sections:
        # rescale sections proportionally to the reduced head_dim
        half = (d_model // n_heads) // 2
        tot = sum(cfg.mrope_sections)
        secs = [max(1, s * half // tot) for s in cfg.mrope_sections]
        secs[-1] += half - sum(secs)
        kw["mrope_sections"] = tuple(secs)
    return dataclasses.replace(cfg, **kw)
