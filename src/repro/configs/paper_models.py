"""The paper's own LLMs (Sec. IV / App. J), as ModelConfigs.

These are the base models the paper LoRA/QLoRA fine-tunes on each quantum
client: Meta-LLaMA-3.2-1B, GPT-2 (1.5B class; we use the 124M "gpt2" layout
the paper's Colab runs realistically used), DeepSeek-LLM-7B-Base.  They are
randomly initialized here (no offline checkpoints) — the *method* (LoRA
fine-tune → loss benchmark → regulation) is what we reproduce.
"""
from repro.configs.base import ModelConfig, LoRAConfig

LLAMA32_1B = ModelConfig(
    name="llama3.2-1b",
    arch_type="dense",
    source="hf:meta-llama/Llama-3.2-1B",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    pattern=(("attn", "mlp"),),
    rope_theta=500000.0,
    tie_embeddings=True,
    lora=LoRAConfig(rank=8, alpha=16.0, dropout=0.05),
    supports_long_decode=True,
    long_decode_window=8192,
)

GPT2 = ModelConfig(
    name="gpt2",
    arch_type="dense",
    source="Radford et al. 2019",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=50304,            # padded 50257 → multiple of 128
    pattern=(("attn", "mlp"),),
    rope_theta=10000.0,          # rotary stand-in for learned positions
    tie_embeddings=True,
    lora=LoRAConfig(rank=8, alpha=16.0),
)

DEEPSEEK_7B = ModelConfig(
    name="deepseek-llm-7b-base",
    arch_type="dense",
    source="hf:deepseek-ai/deepseek-llm-7b-base",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=102400,
    pattern=(("attn", "mlp"),),
    rope_theta=10000.0,
    lora=LoRAConfig(rank=8, alpha=16.0),
)

# Tiny proxy used by the federated driver on CPU: same family as
# llama3.2-1b, small enough to fine-tune from scratch in-process.
TINY_LLM = ModelConfig(
    name="tiny-llm",
    arch_type="dense",
    source="reduced llama family (CPU federated driver)",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    pattern=(("attn", "mlp"),),
    rope_theta=10000.0,
    lora=LoRAConfig(rank=4, alpha=8.0),
)
