"""xlstm-125m [ssm] — sLSTM + mLSTM blocks.

12L d_model=768 4H (kv=4) d_ff=0 (block-internal projections) vocab=50304.
Pattern alternates mLSTM (matrix memory, linear-attention-like, no post-FFN)
and sLSTM (scalar memory + gated FFN).  [arXiv:2405.04517]
"""
from repro.configs.base import ModelConfig, XLSTMConfig, LoRAConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    arch_type="ssm",
    source="arXiv:2405.04517",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    pattern=(("mlstm", "none"), ("slstm", "mlp")),
    xlstm=XLSTMConfig(expand=2, slstm_ffn_factor=4 / 3, conv_width=4),
    lora=LoRAConfig(rank=8, alpha=16.0, targets=("wq", "wkv", "wo")),
    supports_long_decode=True,    # recurrent state: O(1) decode
)
