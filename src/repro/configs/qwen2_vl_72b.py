"""qwen2-vl-72b [vlm] — Qwen2-VL 72B language backbone.

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064 — M-RoPE, dynamic
resolution.  Vision encoder (ViT) is a STUB per the assignment: the
frontend emits precomputed patch embeddings via input_specs().
[arXiv:2409.12191]
"""
from repro.configs.base import ModelConfig, LoRAConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    arch_type="vlm",
    source="arXiv:2409.12191",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    pattern=(("attn", "mlp"),),
    rope_theta=1000000.0,
    mrope_sections=(16, 24, 24),     # temporal/height/width rotary sections
    frontend="vision",
    n_frontend_tokens=1024,          # stub patch embeddings per example
    lora=LoRAConfig(rank=16, alpha=32.0),
    supports_long_decode=True,       # SWA variant for long_500k (beyond-paper)
    long_decode_window=8192,
)
