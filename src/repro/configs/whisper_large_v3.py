"""whisper-large-v3 [audio] — enc-dec speech backbone.

32L d_model=1280 20H (kv=20, i.e. MHA) d_ff=5120 vocab=51866.
Mel-spectrogram + conv frontend is a STUB: input_specs() provides
precomputed frame embeddings (1500 frames).  True whisper-large-v3 is
32 encoder + 32 decoder layers; we implement both stacks (see DESIGN.md §6.5).
long_500k is SKIPPED for this arch (full-attention enc-dec; see DESIGN.md).
[arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig, LoRAConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    arch_type="audio",
    source="arXiv:2212.04356",
    n_layers=32,                  # decoder layers
    n_encoder_layers=32,
    encoder_decoder=True,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    pattern=(("attn", "mlp"),),
    rope_theta=10000.0,           # we use rotary in place of learned-abs pos
    frontend="audio",
    n_frontend_tokens=1500,       # conv-downsampled mel frames
    lora=LoRAConfig(rank=16, alpha=32.0),
    supports_long_decode=False,   # skip long_500k (documented)
)
