"""JAX statevector simulator — the quantum substrate the paper runs on.

Dense statevector of n qubits as a (2,)*n tensor (batchable, jit/vmap
friendly).  Qubit 0 is the leftmost tensor axis (big-endian bitstrings,
matching the parity-interpret convention in ``qnn.py``).

This replaces Qiskit AerSimulator/IBM hardware per the repro≤2 simulation
guidance (DESIGN.md §2) — exact amplitudes, with shot sampling and noise
channels layered on in ``backends.py``.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

CDTYPE = jnp.complex64


def zero_state(n_qubits: int) -> jnp.ndarray:
    psi = jnp.zeros((2,) * n_qubits, CDTYPE)
    return psi.at[(0,) * n_qubits].set(1.0)


def _apply_1q(psi: jnp.ndarray, gate: jnp.ndarray, q: int) -> jnp.ndarray:
    psi = jnp.tensordot(gate, psi, axes=[[1], [q]])
    return jnp.moveaxis(psi, 0, q)


def _apply_2q(psi: jnp.ndarray, gate: jnp.ndarray, q1: int, q2: int
              ) -> jnp.ndarray:
    g = gate.reshape(2, 2, 2, 2)
    psi = jnp.tensordot(g, psi, axes=[[2, 3], [q1, q2]])
    return jnp.moveaxis(psi, (0, 1), (q1, q2))


# --- gate matrices ---------------------------------------------------------
_H = jnp.array([[1, 1], [1, -1]], CDTYPE) / jnp.sqrt(2.0).astype(CDTYPE)
_X = jnp.array([[0, 1], [1, 0]], CDTYPE)
_Z = jnp.array([[1, 0], [0, -1]], CDTYPE)
_I2 = jnp.eye(2, dtype=CDTYPE)


def rx_mat(theta):
    c = jnp.cos(theta / 2).astype(CDTYPE)
    s = (-1j * jnp.sin(theta / 2)).astype(CDTYPE)
    return jnp.stack([jnp.stack([c, s]), jnp.stack([s, c])])


def ry_mat(theta):
    c = jnp.cos(theta / 2).astype(CDTYPE)
    s = jnp.sin(theta / 2).astype(CDTYPE)
    return jnp.stack([jnp.stack([c, -s]), jnp.stack([s, c])])


def rz_mat(theta):
    e = jnp.exp(-0.5j * theta.astype(jnp.complex64))
    z = jnp.zeros((), CDTYPE)
    return jnp.stack([jnp.stack([e, z]), jnp.stack([z, jnp.conj(e)])])


_CX = jnp.array([[1, 0, 0, 0], [0, 1, 0, 0],
                 [0, 0, 0, 1], [0, 0, 1, 0]], CDTYPE)
_CZ = jnp.diag(jnp.array([1, 1, 1, -1], CDTYPE))


# --- public ops ------------------------------------------------------------
def h(psi, q):
    return _apply_1q(psi, _H, q)


def x(psi, q):
    return _apply_1q(psi, _X, q)


def rx(psi, theta, q):
    return _apply_1q(psi, rx_mat(jnp.asarray(theta)), q)


def ry(psi, theta, q):
    return _apply_1q(psi, ry_mat(jnp.asarray(theta)), q)


def rz(psi, theta, q):
    return _apply_1q(psi, rz_mat(jnp.asarray(theta)), q)


def cx(psi, control, target):
    return _apply_2q(psi, _CX, control, target)


def cz(psi, q1, q2):
    return _apply_2q(psi, _CZ, q1, q2)


def crz(psi, theta, control, target):
    th = jnp.asarray(theta).astype(jnp.complex64)
    g = jnp.diag(jnp.concatenate([
        jnp.ones((2,), CDTYPE),
        jnp.stack([jnp.exp(-0.5j * th), jnp.exp(0.5j * th)])]))
    return _apply_2q(psi, g, control, target)


def probabilities(psi: jnp.ndarray) -> jnp.ndarray:
    """|amp|² over the 2**n computational basis (big-endian flatten)."""
    return jnp.abs(psi.reshape(-1)) ** 2


def expect_z(psi: jnp.ndarray, q: int) -> jnp.ndarray:
    p = jnp.abs(psi) ** 2
    axes = tuple(i for i in range(psi.ndim) if i != q)
    pq = p.sum(axis=axes)
    return (pq[0] - pq[1]).real


def norm(psi: jnp.ndarray) -> jnp.ndarray:
    return jnp.sqrt((jnp.abs(psi) ** 2).sum())
