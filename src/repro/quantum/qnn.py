"""SamplerQNN: parameterized-circuit neural networks with parity interpret.

Mirrors the paper's Qiskit ``SamplerQNN`` usage: the circuit's
quasi-probabilities are mapped to discrete classes via a custom interpret
function computing the **parity of the bitstring** (Sec. I-B.2), giving a
binary (or n-class) classifier head on top of a VQC or QCNN.

Two model families (Table II):
  - VQC  : ZZFeatureMap(reps=2) + RealAmplitudes(reps=3)      [Experiment I]
  - QCNN : ZZFeatureMap encoding + conv/pool stages            [Experiment II]
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.quantum import circuits as C
from repro.quantum import statevector as sv


def parity_interpret(probs: jnp.ndarray, n_qubits: int,
                     n_classes: int = 2) -> jnp.ndarray:
    """Map 2**n basis probabilities to class probs by bitstring parity
    (popcount mod n_classes)."""
    idx = jnp.arange(probs.shape[-1])
    pop = jnp.zeros_like(idx)
    for b in range(n_qubits):
        pop = pop + ((idx >> b) & 1)
    cls = pop % n_classes
    onehot = jax.nn.one_hot(cls, n_classes, dtype=probs.dtype)
    return probs @ onehot


def last_qubit_interpret(psi: jnp.ndarray, q: int) -> jnp.ndarray:
    """P(qubit q = 0/1) — QCNN readout on the surviving qubit."""
    p = jnp.abs(psi) ** 2
    axes = tuple(i for i in range(psi.ndim) if i != q)
    pq = p.sum(axis=axes)
    return jnp.stack([pq[0], pq[1]]).real


@dataclass(frozen=True)
class QNNSpec:
    kind: str                  # "vqc" | "qcnn"
    n_qubits: int = 4
    n_classes: int = 2
    fm_reps: int = 2
    ansatz_reps: int = 3

    @property
    def n_params(self) -> int:
        if self.kind == "vqc":
            return C.real_amplitudes_n_params(self.n_qubits,
                                              self.ansatz_reps)
        if self.kind == "qcnn":
            return C.qcnn_n_params(self.n_qubits)
        raise ValueError(self.kind)

    def init_params(self, key) -> jnp.ndarray:
        return jax.random.uniform(key, (self.n_params,), jnp.float32,
                                  -jnp.pi, jnp.pi)


def _forward_one(spec: QNNSpec, theta: jnp.ndarray,
                 x: jnp.ndarray) -> jnp.ndarray:
    """Class probabilities for a single example x (n_qubits features)."""
    psi = C.zz_feature_map(x, reps=spec.fm_reps)
    if spec.kind == "vqc":
        psi = C.real_amplitudes(psi, theta, reps=spec.ansatz_reps)
        probs = sv.probabilities(psi)
        return parity_interpret(probs, spec.n_qubits, spec.n_classes)
    if spec.kind == "qcnn":
        psi, q = C.qcnn(psi, theta)
        out = last_qubit_interpret(psi, q)
        if spec.n_classes == 2:
            return out
        # >2 classes: fall back to parity on the full register
        return parity_interpret(sv.probabilities(psi), spec.n_qubits,
                                spec.n_classes)
    raise ValueError(spec.kind)


def make_forward(spec: QNNSpec) -> Callable:
    """(theta, X (B,n)) -> class probs (B, n_classes), jit-compiled."""
    f = jax.vmap(functools.partial(_forward_one, spec), in_axes=(None, 0))
    return jax.jit(f)


def nll_loss(probs: jnp.ndarray, labels: jnp.ndarray,
             eps: float = 1e-9) -> jnp.ndarray:
    """Mean negative log-likelihood of class probabilities."""
    p = jnp.take_along_axis(probs, labels[:, None], axis=1)[:, 0]
    return -jnp.mean(jnp.log(p + eps))


def accuracy(probs: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(probs, axis=1) == labels).astype(jnp.float32))


def make_loss_fn(spec: QNNSpec, X: jnp.ndarray, y: jnp.ndarray,
                 backend=None) -> Callable:
    """theta -> scalar NLL on (X, y), optionally through a noisy backend.

    With a finite-shot backend (``backend.shots > 0``) the returned loss
    is **keyed** — called as ``loss(theta, key)`` with a per-evaluation
    ``backends.eval_key`` so shot sampling is live and deterministic-by-
    seed; otherwise the channel-only single-argument form is returned.
    """
    fwd = make_forward(spec)

    if backend is not None and backend.shots:
        def loss_sampled(theta, key):
            probs = backend.transform_probs(fwd(theta, X), key)
            return nll_loss(probs, y)

        return jax.jit(loss_sampled)

    def loss(theta):
        probs = fwd(theta, X)
        if backend is not None:
            probs = backend.apply_channel(probs)
        return nll_loss(probs, y)

    return jax.jit(loss)
