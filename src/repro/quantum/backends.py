"""Quantum execution backends: ideal / noisy simulators / emulated QPU.

Replaces AerSimulator, FakeManila and IBM_Brisbane per DESIGN.md §2:
 - exact:    statevector probabilities (AerSimulator, noise-free)
 - aersim:   depolarizing-by-depth + readout bit-flip noise calibrated to
             the "AerSimulator with IBM_Brisbane noise model" setting
 - fake:     FakeManila-style snapshot (stronger readout error, 5 qubits)
 - real:     same noise as aersim plus queue/latency emulation so the
             communication-time accounting of Table I is reproducible

Each backend transforms *class probabilities* (post-interpret) with a noise
channel and optional finite-shot sampling, and reports a wall-time estimate
per evaluation batch (used by bench_backends / bench_comm_cost).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Backend:
    name: str
    depolarizing: float = 0.0     # prob of replacing output by uniform
    readout_flip: float = 0.0     # per-class confusion strength
    shots: int = 0                # 0 = exact probabilities
    # latency model (seconds) — calibrated to Table I comm-time ratios
    t_per_job: float = 0.0        # fixed overhead per optimizer evaluation
    t_per_shot: float = 0.0
    t_queue: float = 0.0          # QPU queue wait per job

    def transform_probs(self, probs: jnp.ndarray,
                        key: Optional[jax.Array] = None) -> jnp.ndarray:
        """Apply noise channel (+ finite shots if key given) to (B, C)."""
        C = probs.shape[-1]
        if self.depolarizing:
            probs = (1 - self.depolarizing) * probs + self.depolarizing / C
        if self.readout_flip:
            # symmetric confusion: stay w.p. 1-f, uniform flip otherwise
            f = self.readout_flip
            conf = (1 - f) * jnp.eye(C) + f / (C - 1) * (1 - jnp.eye(C))
            probs = probs @ conf.astype(probs.dtype)
        if self.shots and key is not None:
            counts = sample_counts(key, probs, self.shots)
            probs = counts / self.shots
        return probs

    def eval_time(self, n_circuits: int) -> float:
        """Estimated wall-time for one optimizer evaluation over a batch."""
        return (self.t_queue + self.t_per_job
                + self.t_per_shot * max(self.shots, 1) * n_circuits)


def sample_counts(key, probs: jnp.ndarray, shots: int) -> jnp.ndarray:
    """Multinomial shot sampling per row of (B, C) probabilities.

    O(B·C + B·shots) memory: inverse-CDF sampling — per-row cumulative
    probabilities (B, C), uniform draws (shots, B) located by a batched
    ``searchsorted``, scatter-added straight into the (B, C) count
    matrix.  (``jax.random.categorical`` would materialize a
    (shots, B, C) gumbel tensor internally.)
    """
    B, C = probs.shape
    cdf = jnp.cumsum(jnp.clip(probs, 0.0, 1.0), axis=-1)       # (B, C)
    # renormalize — the old categorical path did so implicitly via logits
    cdf = cdf / jnp.maximum(cdf[:, -1:], 1e-12)
    u = jax.random.uniform(key, (shots, B), cdf.dtype)
    draws = jax.vmap(
        lambda row_cdf, row_u: jnp.searchsorted(row_cdf, row_u,
                                                side="right"),
        in_axes=(0, 1), out_axes=1)(cdf, u)                    # (shots, B)
    draws = jnp.minimum(draws, C - 1)      # cumsum rounding below 1.0
    counts = jnp.zeros((B, C), jnp.float32)
    return counts.at[jnp.arange(B)[None, :], draws].add(1.0)


# Calibrated instances.  Latencies reproduce Table-I orderings:
# Fake ≈ 162.9s, AerSim ≈ 325.0s, Real ≈ 1395.9s for Exp-1-sized runs.
EXACT = Backend("exact")
FAKE = Backend("fake", depolarizing=0.015, readout_flip=0.03, shots=100,
               t_per_job=0.02, t_per_shot=1.2e-4)
AERSIM = Backend("aersim", depolarizing=0.03, readout_flip=0.015, shots=100,
                 t_per_job=0.04, t_per_shot=2.4e-4)
REAL = Backend("real", depolarizing=0.035, readout_flip=0.02, shots=100,
               t_per_job=0.05, t_per_shot=2.4e-4, t_queue=1.55)

BACKENDS = {b.name: b for b in (EXACT, FAKE, AERSIM, REAL)}


def get(name: str) -> Backend:
    return BACKENDS[name]
