"""Quantum execution backends: ideal / noisy simulators / emulated QPU.

Replaces AerSimulator, FakeManila and IBM_Brisbane per DESIGN.md §2:
 - exact:    statevector probabilities (AerSimulator, noise-free)
 - aersim:   depolarizing-by-depth + readout bit-flip noise calibrated to
             the "AerSimulator with IBM_Brisbane noise model" setting
 - fake:     FakeManila-style snapshot (stronger readout error, 5 qubits)
 - real:     same noise as aersim plus queue/latency emulation so the
             communication-time accounting of Table I is reproducible

Each backend transforms *class probabilities* (post-interpret) in two
stages — a deterministic noise channel and keyed finite-shot sampling —
and reports a wall-time estimate per evaluation batch (used by
bench_backends / bench_comm_cost).

Key-derivation contract
-----------------------
Finite-shot sampling is deterministic-by-seed and identical across the
sequential and batched engines.  Every objective evaluation draws its
shots from

    ``eval_key(PRNGKey(seed), round, client, slot)``
    = ``fold_in(fold_in(fold_in(PRNGKey(seed), round), client), slot)``

where ``slot`` is the evaluation's *structural position* in the round's
schedule — not a running counter.  Structural slots are what make
engine parity possible: the batched Nelder–Mead evaluates every
speculative candidate while the sequential method evaluates lazily, so a
counter would desynchronize, but the reflect point of iteration ``i``
always owns the same slot in both engines.  The schedule (``n`` = number
of parameters):

  Nelder–Mead:  init simplex row ``r``            → slot ``r``  (0..n)
                iteration ``i`` (global, resumes included),
                ``base = (n+1) + i·(n+3)``:
                reflect → ``base``, expand → ``base+1``,
                contract → ``base+2``, shrink row ``j`` → ``base+2+j``
  SPSA:         init → slot 0; iteration ``k`` (global):
                f(x+ckδ) → ``1+3k``, f(x−ckδ) → ``2+3k``,
                candidate → ``3+3k``; final polish → ``FINAL_EVAL_SLOT``
  Reporting:    the orchestrator's per-round client-loss report uses
                ``REPORT_EVAL_SLOT`` on the client's stream; server-side
                evaluations use the reserved client id
                ``SERVER_CLIENT`` with slots ``SERVER_SLOT_*``.
  Population:   the fused multi-round driver's per-round cohort
                subsample draws from the reserved ``POP_CLIENT`` stream
                at ``POP_SLOT_COHORT``; a client's dropout coin draws
                from the client's **own** stream at
                ``DROPOUT_EVAL_SLOT`` — a pure function of
                ``(seed, round, client)``, so whether a client drops is
                independent of cohort size or composition and
                participation sweeps at one seed stay comparable.

``apply_channel`` is traceable with no key; ``transform_probs`` *raises*
when ``shots > 0`` and no key is supplied — a finite-shot backend must
never silently fall back to deterministic channel-only evaluation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

# Reserved slot / client ids of the key-derivation contract (see module
# docstring).  Slots are int32; optimizer schedules use small non-negative
# slots, so the reserved ids live at the edges of the range.
FINAL_EVAL_SLOT = 0x7FFFFFFF      # SPSA's post-loop polish evaluation
REPORT_EVAL_SLOT = 0x7FFFFFFE     # orchestrator per-client loss report
DROPOUT_EVAL_SLOT = 0x7FFFFFFD    # per-round dropout coin on the
                                  # client's own stream (fused driver)
SERVER_CLIENT = 0x7FFFFFFF        # server-side evals (not a device id;
                                  # fold_in coerces to uint32, so ids
                                  # must be non-negative)
POP_CLIENT = 0x7FFFFFFD           # population-control stream: cohort
                                  # subsampling draws (fused driver)
POP_SLOT_COHORT = 0               # per-round cohort subsample draw
SERVER_SLOT_LOSS_PRE = 0          # server loss of θ_g before aggregation
SERVER_SLOT_LOSS_POST = 1         # server loss after aggregation
SERVER_SLOT_VAL_ACC = 2
SERVER_SLOT_TEST_ACC = 3


def eval_key(base_key: jax.Array, round_idx, client, slot) -> jax.Array:
    """The contract's key chain; every argument past the first may be a
    traced integer (usable under ``jit`` / ``vmap`` / ``fori_loop``)."""
    k = jax.random.fold_in(base_key, round_idx)
    k = jax.random.fold_in(k, client)
    return jax.random.fold_in(k, slot)


@dataclass(frozen=True)
class Backend:
    name: str
    depolarizing: float = 0.0     # prob of replacing output by uniform
    readout_flip: float = 0.0     # per-class confusion strength
    shots: int = 0                # 0 = exact probabilities
    # latency model (seconds) — calibrated to Table I comm-time ratios
    t_per_job: float = 0.0        # fixed overhead per optimizer evaluation
    t_per_shot: float = 0.0
    t_queue: float = 0.0          # QPU queue wait per job

    def apply_channel(self, probs: jnp.ndarray) -> jnp.ndarray:
        """Deterministic noise channel on (B, C) class probabilities.

        Traceable, key-free: safe inside ``vmap``/``fori_loop`` bodies and
        for channel-only evaluation (``shots == 0`` or explicit
        measurement without sampling).
        """
        C = probs.shape[-1]
        if self.depolarizing:
            probs = (1 - self.depolarizing) * probs + self.depolarizing / C
        if self.readout_flip:
            # symmetric confusion: stay w.p. 1-f, uniform flip otherwise
            f = self.readout_flip
            conf = (1 - f) * jnp.eye(C) + f / (C - 1) * (1 - jnp.eye(C))
            probs = probs @ conf.astype(probs.dtype)
        return probs

    def sample(self, probs: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
        """Finite-shot readout: empirical frequencies of ``shots`` draws
        per row.  Identity when ``shots == 0``."""
        if not self.shots:
            return probs
        counts = sample_counts(key, probs, self.shots)
        # multiply by the host-rounded reciprocal: XLA strength-reduces
        # a divide-by-constant the same way, so eager and jitted
        # evaluation of the same draws stay bitwise identical
        return counts * (1.0 / self.shots)

    def transform_probs(self, probs: jnp.ndarray,
                        key: Optional[jax.Array] = None) -> jnp.ndarray:
        """Channel + finite-shot sampling on (B, C).

        Raises when ``shots > 0`` and no key is supplied: a finite-shot
        backend evaluated without a key would silently revert to the
        deterministic channel, which is exactly the bug class this
        contract exists to prevent.  Channel-only evaluation is an
        explicit choice — call ``apply_channel``.
        """
        probs = self.apply_channel(probs)
        if self.shots:
            if key is None:
                raise ValueError(
                    f"backend {self.name!r} has shots={self.shots} but "
                    "transform_probs was called without a PRNG key; pass "
                    "an eval_key(...) or use apply_channel() for "
                    "channel-only evaluation")
            probs = self.sample(probs, key)
        return probs

    def eval_time(self, n_circuits: int) -> float:
        """Estimated wall-time for one optimizer evaluation over a batch."""
        return (self.t_queue + self.t_per_job
                + self.t_per_shot * max(self.shots, 1) * n_circuits)


def sample_counts(key, probs: jnp.ndarray, shots: int) -> jnp.ndarray:
    """Multinomial shot sampling per row of (B, C) probabilities.

    O(B·C + B·shots) memory: inverse-CDF sampling — per-row cumulative
    probabilities (B, C), uniform draws (shots, B) located by a batched
    ``searchsorted``, scatter-added straight into the (B, C) count
    matrix.  (``jax.random.categorical`` would materialize a
    (shots, B, C) gumbel tensor internally.)

    Degenerate rows with (numerically) zero mass — all entries clipped
    to 0 — fall back to the uniform distribution instead of dumping
    every shot into class ``C-1`` via the clamped ``searchsorted``.
    **NaN rows are not degenerate — they are diverged**: their counts
    come back all-NaN so the client's loss stays NaN and
    ``selection.py``'s +inf hardening sorts it last, instead of the
    uniform fallback laundering divergence into a plausible finite
    loss.  (The NaN row is sampled internally as uniform so every other
    row consumes exactly the same draws — finite rows are bitwise
    unchanged by the overwrite, preserving the pinned parity seeds.)
    Counts are returned in ``probs.dtype`` but accumulated in float32:
    scatter-adding in a low-precision dtype would saturate (bfloat16
    stops incrementing at 256) and silently lose shots.
    """
    B, C = probs.shape
    nan_row = jnp.any(jnp.isnan(probs), axis=-1, keepdims=True)  # (B, 1)
    p = jnp.clip(probs, 0.0, 1.0)
    p = jnp.where(nan_row, jnp.ones_like(p) / C, p)   # draw-stable stand-in
    mass = jnp.sum(p, axis=-1, keepdims=True)
    p = jnp.where(mass > 1e-12, p, jnp.ones_like(p) / C)
    cdf = jnp.cumsum(p, axis=-1)                               # (B, C)
    # renormalize — the old categorical path did so implicitly via logits
    cdf = cdf / cdf[:, -1:]
    u = jax.random.uniform(key, (shots, B), cdf.dtype)
    draws = jax.vmap(
        lambda row_cdf, row_u: jnp.searchsorted(row_cdf, row_u,
                                                side="right"),
        in_axes=(0, 1), out_axes=1)(cdf, u)                    # (shots, B)
    draws = jnp.minimum(draws, C - 1)      # cumsum rounding below 1.0
    counts = jnp.zeros((B, C), jnp.float32)
    counts = counts.at[jnp.arange(B)[None, :], draws].add(1.0)
    counts = jnp.where(nan_row, jnp.nan, counts)      # divergence surfaces
    return counts.astype(probs.dtype)


# Calibrated instances.  Latencies reproduce Table-I orderings:
# Fake ≈ 162.9s, AerSim ≈ 325.0s, Real ≈ 1395.9s for Exp-1-sized runs.
EXACT = Backend("exact")
FAKE = Backend("fake", depolarizing=0.015, readout_flip=0.03, shots=100,
               t_per_job=0.02, t_per_shot=1.2e-4)
AERSIM = Backend("aersim", depolarizing=0.03, readout_flip=0.015, shots=100,
                 t_per_job=0.04, t_per_shot=2.4e-4)
REAL = Backend("real", depolarizing=0.035, readout_flip=0.02, shots=100,
               t_per_job=0.05, t_per_shot=2.4e-4, t_queue=1.55)

BACKENDS = {b.name: b for b in (EXACT, FAKE, AERSIM, REAL)}


def get(name: str) -> Backend:
    return BACKENDS[name]
