"""Circuit tape compiler: flat gate tapes executed by one fused program.

The eager circuits in ``circuits.py`` apply one gate at a time through
``tensordot``/``moveaxis`` on a ``(2,)*n`` tensor — correct, but the
federated hot path pays Python dispatch per gate per example.  Here the
same circuits are compiled **once** into a flat tape of

  (gate_id, target, control, angle-source)

rows and replayed with ``lax.scan`` over a single batched gate kernel that
operates on ``(B, 2**n)`` flattened statevectors.  Every gate the paper's
three circuits need reduces to an (optionally controlled) 2×2 unitary:

  H, P(θ), RY(θ), RZ(θ), and CX = controlled-X.

Angle sources cover the three ways an angle is produced:

  - a constant (QCNN's ±π/2 frame rotations),
  - a feature term (``2·x[i]`` or the ZZ phase ``2(π−x_i)(π−x_j)``),
  - a trainable parameter ``theta[k]``.

``angle = const + feature_term + theta_pad[theta_idx]`` with
``theta_pad = [0, *theta]`` so index 0 means "no parameter".

Qubit convention matches ``statevector.py``: qubit 0 is the leftmost
tensor axis, i.e. bit ``n-1-q`` of the flat big-endian index.

The batched gate apply has three interchangeable implementations:
the fused jnp path below (default), the ``kernels/statevector_gates.py``
Pallas kernel (``gate_apply=tape.pallas_gate_apply``), and the
``kernels/ref.py`` oracle — all contracted equal by ``tests/test_tape.py``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.quantum import statevector as sv

GATE_H, GATE_P, GATE_RY, GATE_RZ, GATE_X = 0, 1, 2, 3, 4

XMODE_NONE, XMODE_LINEAR, XMODE_ZZ = 0, 1, 2


@dataclass(frozen=True)
class GateTape:
    """Flat compiled circuit: parallel arrays, one row per gate."""
    n_qubits: int
    gate_id: np.ndarray      # (G,) int32 in {H, P, RY, RZ, X}
    target: np.ndarray       # (G,) int32
    control: np.ndarray      # (G,) int32, -1 = uncontrolled
    const: np.ndarray        # (G,) float32 additive constant angle
    xmode: np.ndarray        # (G,) int32 ∈ {NONE, LINEAR, ZZ}
    xi: np.ndarray           # (G,) int32 feature index i
    xj: np.ndarray           # (G,) int32 feature index j (ZZ only)
    theta_idx: np.ndarray    # (G,) int32 into [0, *theta]; 0 = none

    @property
    def n_gates(self) -> int:
        return int(self.gate_id.shape[0])


class TapeBuilder:
    def __init__(self, n_qubits: int):
        self.n_qubits = n_qubits
        self._rows: List[Tuple] = []

    def _add(self, gid, target, control=-1, const=0.0, xmode=XMODE_NONE,
             xi=0, xj=0, theta=-1):
        self._rows.append((gid, target, control, const, xmode, xi, xj,
                           theta + 1))

    def h(self, q):
        self._add(GATE_H, q)

    def p_linear(self, q, feat):
        """P(2·x[feat]) on qubit q (ZZFeatureMap single-qubit phase)."""
        self._add(GATE_P, q, xmode=XMODE_LINEAR, xi=feat)

    def p_zz(self, q, fi, fj):
        """P(2·(π−x[fi])(π−x[fj])) on qubit q (ZZ entangling phase)."""
        self._add(GATE_P, q, xmode=XMODE_ZZ, xi=fi, xj=fj)

    def ry_theta(self, q, k):
        self._add(GATE_RY, q, theta=k)

    def rz_theta(self, q, k):
        self._add(GATE_RZ, q, theta=k)

    def rz_const(self, q, angle):
        self._add(GATE_RZ, q, const=angle)

    def cx(self, control, target):
        self._add(GATE_X, target, control=control)

    def build(self) -> GateTape:
        cols = list(zip(*self._rows))
        i32 = functools.partial(np.asarray, dtype=np.int32)
        return GateTape(
            n_qubits=self.n_qubits,
            gate_id=i32(cols[0]), target=i32(cols[1]), control=i32(cols[2]),
            const=np.asarray(cols[3], np.float32), xmode=i32(cols[4]),
            xi=i32(cols[5]), xj=i32(cols[6]), theta_idx=i32(cols[7]))


# ---------------------------------------------------------------------------
# compilers — mirror circuits.py gate-for-gate (tests/test_tape.py guards
# drift against the eager implementations)
# ---------------------------------------------------------------------------
def compile_zz_feature_map(tb: TapeBuilder, *, reps: int = 2) -> None:
    n = tb.n_qubits
    for _ in range(reps):
        for q in range(n):
            tb.h(q)
            tb.p_linear(q, q)
        for i in range(n):
            for j in range(i + 1, n):
                tb.cx(i, j)
                tb.p_zz(j, i, j)
                tb.cx(i, j)


def compile_real_amplitudes(tb: TapeBuilder, *, reps: int = 3,
                            entangle: str = "full") -> None:
    n = tb.n_qubits
    for r in range(reps):
        for q in range(n):
            tb.ry_theta(q, r * n + q)
        if entangle == "full":
            pairs = [(i, j) for i in range(n) for j in range(i + 1, n)]
        else:
            pairs = [(i, i + 1) for i in range(n - 1)]
        for (i, j) in pairs:
            tb.cx(i, j)
    for q in range(n):
        tb.ry_theta(q, reps * n + q)


def _compile_conv2(tb, k, q1, q2):
    tb.rz_const(q2, -np.pi / 2)
    tb.cx(q2, q1)
    tb.rz_theta(q1, k)
    tb.ry_theta(q2, k + 1)
    tb.cx(q1, q2)
    tb.ry_theta(q2, k + 2)
    tb.cx(q2, q1)
    tb.rz_const(q1, np.pi / 2)


def _compile_pool2(tb, k, src, dst):
    tb.rz_const(dst, -np.pi / 2)
    tb.cx(dst, src)
    tb.rz_theta(src, k)
    tb.ry_theta(dst, k + 1)
    tb.cx(src, dst)
    tb.ry_theta(dst, k + 2)


def compile_qcnn(tb: TapeBuilder) -> int:
    """QCNN conv/pool stages; returns the readout qubit index."""
    active = list(range(tb.n_qubits))
    k = 0
    while len(active) > 1:
        pairs = [(active[2 * i], active[2 * i + 1])
                 for i in range(len(active) // 2)]
        for (a, b) in pairs:
            _compile_conv2(tb, k, a, b)
            k += 3
        survivors = []
        for (a, b) in pairs:
            _compile_pool2(tb, k, a, b)
            k += 3
            survivors.append(b)
        if len(active) % 2:
            survivors.append(active[-1])
        active = survivors
    return active[0]


@dataclass(frozen=True)
class CompiledQNN:
    """A QNNSpec lowered to a tape + readout recipe."""
    kind: str
    n_qubits: int
    n_classes: int
    tape: GateTape
    readout: int = -1        # QCNN surviving qubit; -1 = parity interpret


def compile_qnn(spec) -> CompiledQNN:
    """Lower a ``qnn.QNNSpec`` to a ``CompiledQNN``."""
    tb = TapeBuilder(spec.n_qubits)
    compile_zz_feature_map(tb, reps=spec.fm_reps)
    readout = -1
    if spec.kind == "vqc":
        compile_real_amplitudes(tb, reps=spec.ansatz_reps)
    elif spec.kind == "qcnn":
        readout = compile_qcnn(tb)
    else:
        raise ValueError(spec.kind)
    return CompiledQNN(spec.kind, spec.n_qubits, spec.n_classes,
                       tb.build(), readout)


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------
def tape_angles(tape: GateTape, X: jnp.ndarray,
                theta: jnp.ndarray) -> jnp.ndarray:
    """Resolve per-gate angles for a batch of examples → (B, G) float32."""
    xi = X[:, tape.xi]                                   # (B, G)
    xj = X[:, tape.xj]
    xterm = jnp.where(
        tape.xmode == XMODE_LINEAR, 2.0 * xi,
        jnp.where(tape.xmode == XMODE_ZZ,
                  2.0 * (jnp.pi - xi) * (jnp.pi - xj), 0.0))
    theta_pad = jnp.concatenate(
        [jnp.zeros((1,), theta.dtype), theta.astype(jnp.float32)])
    return tape.const[None, :] + xterm + theta_pad[tape.theta_idx][None, :]


def _mat_h(ang):
    return jnp.broadcast_to(sv._H, (ang.shape[0], 2, 2))


def _mat_p(ang):
    th = ang.astype(jnp.complex64)
    one, zero = jnp.ones_like(th), jnp.zeros_like(th)
    return jnp.stack([jnp.stack([one, zero], -1),
                      jnp.stack([zero, jnp.exp(1j * th)], -1)], -2)


def _mat_ry(ang):
    c = jnp.cos(ang / 2).astype(sv.CDTYPE)
    s = jnp.sin(ang / 2).astype(sv.CDTYPE)
    return jnp.stack([jnp.stack([c, -s], -1),
                      jnp.stack([s, c], -1)], -2)


def _mat_rz(ang):
    e = jnp.exp(-0.5j * ang.astype(jnp.complex64))
    zero = jnp.zeros_like(e)
    return jnp.stack([jnp.stack([e, zero], -1),
                      jnp.stack([zero, jnp.conj(e)], -1)], -2)


def _mat_x(ang):
    return jnp.broadcast_to(sv._X, (ang.shape[0], 2, 2))


_MAT_FNS = (_mat_h, _mat_p, _mat_ry, _mat_rz, _mat_x)


def pair_indices(target, control, n_qubits: int):
    """Index pairs (amp with target bit 0, partner) + control mask.

    Returns (idx0, idx1) each (2**n / 2,) int32 and cmask (2**n / 2,) bool —
    True where the gate acts (control bit set, or no control).
    """
    half = (1 << n_qubits) // 2
    shift = n_qubits - 1 - target
    stride = jnp.left_shift(1, shift)
    k = jnp.arange(half, dtype=jnp.int32)
    idx0 = ((k >> shift) << (shift + 1)) | (k & (stride - 1))
    idx1 = idx0 | stride
    cshift = jnp.where(control < 0, 0, n_qubits - 1 - control)
    cmask = jnp.where(control < 0, True, ((idx0 >> cshift) & 1) == 1)
    return idx0, idx1, cmask


def jnp_gate_apply(psi, g, target, control, n_qubits: int):
    """Fused batched (controlled) 2×2 gate on (B, 2**n) statevectors."""
    idx0, idx1, cmask = pair_indices(target, control, n_qubits)
    a0, a1 = psi[:, idx0], psi[:, idx1]
    n0 = g[:, 0, 0, None] * a0 + g[:, 0, 1, None] * a1
    n1 = g[:, 1, 0, None] * a0 + g[:, 1, 1, None] * a1
    n0 = jnp.where(cmask[None, :], n0, a0)
    n1 = jnp.where(cmask[None, :], n1, a1)
    return psi.at[:, idx0].set(n0).at[:, idx1].set(n1)


def pallas_gate_apply(psi, g, target, control, n_qubits: int):
    """Same contract as ``jnp_gate_apply`` through the Pallas kernel."""
    from repro.kernels import ops
    idx0, idx1, cmask = pair_indices(target, control, n_qubits)
    re, im = ops.statevector_gate(
        jnp.real(psi), jnp.imag(psi), jnp.real(g), jnp.imag(g),
        idx0, idx1, cmask.astype(jnp.float32))
    return jax.lax.complex(re, im).astype(psi.dtype)


def run_tape(tape: GateTape, angles: jnp.ndarray, *,
             gate_apply: Optional[Callable] = None) -> jnp.ndarray:
    """Replay the tape on |0…0⟩ for a batch → (B, 2**n) complex64."""
    apply_fn = gate_apply or jnp_gate_apply
    B = angles.shape[0]
    psi0 = jnp.zeros((B, 1 << tape.n_qubits), sv.CDTYPE).at[:, 0].set(1.0)
    xs = (jnp.asarray(tape.gate_id), jnp.asarray(tape.target),
          jnp.asarray(tape.control), angles.T)

    def step(psi, x):
        gid, tq, cq, ang = x
        g = jax.lax.switch(gid, _MAT_FNS, ang)
        return apply_fn(psi, g, tq, cq, tape.n_qubits), None

    psi, _ = jax.lax.scan(step, psi0, xs)
    return psi


def tape_probs(cq: CompiledQNN, theta: jnp.ndarray, X: jnp.ndarray, *,
               gate_apply: Optional[Callable] = None) -> jnp.ndarray:
    """Class probabilities (B, n_classes), matching ``qnn._forward_one``."""
    from repro.quantum import qnn
    angles = tape_angles(cq.tape, X, theta)
    psi = run_tape(cq.tape, angles, gate_apply=gate_apply)
    probs = jnp.abs(psi) ** 2                            # (B, 2**n)
    if cq.kind == "qcnn" and cq.n_classes == 2:
        B = probs.shape[0]
        q = cq.readout
        grouped = probs.reshape(B, 1 << q, 2, -1)
        return grouped.sum(axis=(1, 3))
    return qnn.parity_interpret(probs, cq.n_qubits, cq.n_classes)


def make_tape_forward(spec, *, gate_apply: Optional[Callable] = None
                      ) -> Callable:
    """(theta, X (B,n)) → class probs (B, n_classes); drop-in for
    ``qnn.make_forward`` backed by the compiled tape."""
    cq = compile_qnn(spec)
    return jax.jit(functools.partial(tape_probs, cq, gate_apply=gate_apply))
