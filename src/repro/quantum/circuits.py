"""Parameterized quantum circuits used by the paper.

All circuits are pure functions ``(features/params) -> statevector`` built
on ``repro.quantum.statevector`` — jit/vmap-friendly, CPU-exact.

 - ``zz_feature_map``  : Qiskit ZZFeatureMap (H + P(2x_i) + pairwise
   ZZ-phase entanglement), the paper's VQC encoder (Fig. 15).
 - ``real_amplitudes`` : Qiskit RealAmplitudes ansatz (ry layers + CX
   entanglement), the paper's VQC ansatz.
 - ``qcnn``            : quantum convolutional NN (alternating 2-qubit conv
   unitaries + pooling that halves the active register), App. D / Fig. 14.
"""
from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.quantum import statevector as sv


# ---------------------------------------------------------------------------
# feature maps
# ---------------------------------------------------------------------------
def _p_phase(psi, theta, q):
    """Phase gate P(θ) = diag(1, e^{iθ}) — rz up to global phase; we apply
    the exact diag to keep amplitudes Qiskit-comparable."""
    th = jnp.asarray(theta).astype(jnp.complex64)
    g = jnp.stack([jnp.stack([jnp.ones((), sv.CDTYPE), jnp.zeros((), sv.CDTYPE)]),
                   jnp.stack([jnp.zeros((), sv.CDTYPE), jnp.exp(1j * th)])])
    return sv._apply_1q(psi, g, q)


def zz_feature_map(x: jnp.ndarray, *, reps: int = 2) -> jnp.ndarray:
    """ZZFeatureMap(n_qubits=len(x), reps).  x: (n,) float features."""
    n = x.shape[0]
    psi = sv.zero_state(n)
    for _ in range(reps):
        for q in range(n):
            psi = sv.h(psi, q)
            psi = _p_phase(psi, 2.0 * x[q], q)
        for i in range(n):
            for j in range(i + 1, n):
                phi = 2.0 * (jnp.pi - x[i]) * (jnp.pi - x[j])
                psi = sv.cx(psi, i, j)
                psi = _p_phase(psi, phi, j)
                psi = sv.cx(psi, i, j)
    return psi


# ---------------------------------------------------------------------------
# ansatz
# ---------------------------------------------------------------------------
def real_amplitudes_n_params(n_qubits: int, reps: int = 3) -> int:
    return n_qubits * (reps + 1)


def real_amplitudes(psi: jnp.ndarray, theta: jnp.ndarray, *,
                    reps: int = 3, entangle: str = "full") -> jnp.ndarray:
    """RealAmplitudes ansatz applied to ``psi``.  theta: (n*(reps+1),)."""
    n = psi.ndim
    theta = theta.reshape(reps + 1, n)
    for r in range(reps):
        for q in range(n):
            psi = sv.ry(psi, theta[r, q], q)
        if entangle == "full":
            pairs = [(i, j) for i in range(n) for j in range(i + 1, n)]
        else:  # linear
            pairs = [(i, i + 1) for i in range(n - 1)]
        for (i, j) in pairs:
            psi = sv.cx(psi, i, j)
    for q in range(n):
        psi = sv.ry(psi, theta[reps, q], q)
    return psi


# ---------------------------------------------------------------------------
# QCNN (App. D): conv + pool 2-qubit primitives, log2(n) stages
# ---------------------------------------------------------------------------
def _conv2(psi, p, q1, q2):
    """Qiskit-tutorial conv circuit: 3 params per 2-qubit block."""
    psi = sv.rz(psi, -jnp.pi / 2, q2)
    psi = sv.cx(psi, q2, q1)
    psi = sv.rz(psi, p[0], q1)
    psi = sv.ry(psi, p[1], q2)
    psi = sv.cx(psi, q1, q2)
    psi = sv.ry(psi, p[2], q2)
    psi = sv.cx(psi, q2, q1)
    psi = sv.rz(psi, jnp.pi / 2, q1)
    return psi


def _pool2(psi, p, src, dst):
    """Pooling: entangle src→dst then discard src from the active set."""
    psi = sv.rz(psi, -jnp.pi / 2, dst)
    psi = sv.cx(psi, dst, src)
    psi = sv.rz(psi, p[0], src)
    psi = sv.ry(psi, p[1], dst)
    psi = sv.cx(psi, src, dst)
    psi = sv.ry(psi, p[2], dst)
    return psi


def qcnn_n_params(n_qubits: int) -> int:
    """3 params per conv pair + 3 per pool pair per stage."""
    n, total = n_qubits, 0
    while n > 1:
        pairs = n // 2
        total += 3 * pairs          # conv
        total += 3 * pairs          # pool
        n -= pairs
    return total


def qcnn(psi: jnp.ndarray, theta: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
    """Apply QCNN stages; returns (psi, final_qubit_index).

    Active register starts as all qubits; each stage convolves adjacent
    pairs then pools the first of each pair into the second, halving the
    register until one qubit remains (classification readout qubit).
    """
    n = psi.ndim
    active = list(range(n))
    k = 0
    while len(active) > 1:
        pairs = [(active[2 * i], active[2 * i + 1])
                 for i in range(len(active) // 2)]
        for (a, b) in pairs:
            psi = _conv2(psi, theta[k:k + 3], a, b)
            k += 3
        survivors = []
        for (a, b) in pairs:
            psi = _pool2(psi, theta[k:k + 3], a, b)
            k += 3
            survivors.append(b)
        if len(active) % 2:
            survivors.append(active[-1])
        active = survivors
    return psi, active[0]
