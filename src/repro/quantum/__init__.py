from repro.quantum import backends, circuits, qnn, statevector, tape  # noqa: F401
