from repro.quantum import backends, circuits, qnn, statevector  # noqa: F401
