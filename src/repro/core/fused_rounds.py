"""Fused multi-round federation driver — R rounds as ONE device program.

The host orchestrator (``core/orchestrator.py``) exits the device every
round for FedAvg, regulation, selection, termination, and the per-client
loss report — at small client counts the host round-trip, not the
quantum circuit, is the wall-time ceiling (ROADMAP).  This module runs
the **entire round loop** as a single jitted ``lax.scan`` over rounds:

    carry = (θ_g, budgets, last_losses, cum_evals,
             prev_server_loss, small_count, done_flag)

with every host-side step replaced by a traceable twin of the reference
module it mirrors:

  - **FedAvg** — masked weighted mean of the trained ``(C, P)`` stack on
    device (the host aggregates in float64; the fused program is float32,
    so θ_g trajectories agree to f32 tolerance while every quantized
    quantity below is exact).
  - **Regulation** — ``regulate_batched``, a vectorized twin of
    ``regulation.regulate`` (same guard ladder, same round-half-to-even,
    same ``[min_iter, cap]`` clamp), applied as a masked integer budget
    update: only eligible cohort members after round 1.
  - **Selection** — ``select_topk_mask``, the mask form of
    ``selection.select_aligned``: top-k over ``|L_i − L_s|`` with
    NaN/inf hardened to +inf (sorts last) and stable ties (lower index
    wins), intersected with the round's eligibility mask.
  - **Termination** — ``termination_step``, the per-round transition of
    ``TerminationCriterion`` (relative-improvement + patience, t_max
    short-circuit *before* the patience update, exactly like the host
    class).  The resulting ``done`` flag masks every carry update of
    post-convergence rounds, so an early-terminated fused run is
    bit-identical in state to one that stopped the scan.
  - **Reporting** — per-client losses are computed inside the scan body
    (masked NLL at ``REPORT_EVAL_SLOT`` on the client's key stream) and
    returned in the scanned outputs: one device→host transfer per run,
    not C per round as in the orchestrator's ``_nll`` loop.

Population semantics
--------------------
On top of the fused loop, the driver supports a client *population*
C_pop ≫ C_round.  Per round ``t`` it draws a cohort of ``c_round``
distinct population ids from the reserved ``POP_CLIENT`` stream
(``eval_key(base, t, POP_CLIENT, POP_SLOT_COHORT)``), gathers the
cohort's rows out of the ``(C_pop, …)`` data/budget/loss/delta stacks,
runs the round on the ``(c_round, …)`` slices, and scatters budgets /
last losses / cumulative evals back.  A ``dropout`` probability
additionally drops each cohort member by a coin on the **client's own**
stream (``DROPOUT_EVAL_SLOT``) — dropped or outside-cohort clients are
bitwise untouched: their carry rows keep their prior values, their key
streams are pure functions of ``(seed, round, client_id)`` and never
shift with cohort composition, and their eval spend is 0 (the batched
optimizers' ``active`` mask).  That inertness is what makes
participation sweeps at one seed comparable (``tests/test_fused_rounds``
pins it).

Sharding: under full participation the client stacks shard over the
existing ``'clients'`` mesh (``put_client_stacks``; the population axis
IS the client axis).  In population mode the layout flips: the
``(C_pop, …)`` population state is **replicated** and only the gathered
``(c_round, …)`` cohort — the round's compute — is pinned to the mesh
(``constrain_client_axis``; the carries stay replicated via
``constrain_replicated``).  Sharding the population stacks instead
turns every round's dynamic gather/scatter into a cross-device
collective chain inside the scan that costs more than the round itself.
``c_round`` must divide the mesh width.

Parity contract (``tests/test_fused_rounds.py``): a fused run with full
participation matches the host orchestrator round-for-round at pinned
seeds — selected sets, regulated budgets, eval counts, and the
termination round **exactly**; θ_g, client losses, and server metrics to
f32 tolerance (the host aggregates and divides in float64).  Finite-shot
draws are identical by the ``eval_key`` contract; note the report-eval
draw shape is the padded ``(Bmax, n_classes)``, so loss parity with the
host's unpadded ``_nll`` is bitwise only for equal client shards.
``run_host_reference`` extends the same oracle to population mode
(cohorts, dropout) for the semantics the orchestrator cannot express.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import regulation as regulation_mod
from repro.core.batched_engine import build_local_phase
from repro.core.termination import TerminationCriterion
from repro.distributed import sharding as shd
from repro.optim.batched_spsa import make_deltas
from repro.quantum import backends as backend_mod
from repro.quantum import qnn, tape as tape_mod

_FUSED_CACHE: Dict[tuple, object] = {}


# ---------------------------------------------------------------------------
# traceable twins of the host-side round steps
# ---------------------------------------------------------------------------
def regulate_batched(maxiter, qnn_loss, llm_loss, *, variant: str = "adaptive",
                     cap: int = 100, min_iter: int = 1, weight: float = 0.5,
                     increment: int = 2):
    """Vectorized twin of ``regulation.regulate`` — same guard ladder,
    same formulas, same clamp, elementwise over ``(C,)`` stacks.

    Guard order (must mirror the host function exactly):
      1. llm_loss <= 0 or non-finite  → maxiter unchanged (no clamp!),
      2. qnn_loss non-finite          → clamp(maxiter) (hold the budget),
      3. qnn_loss <= llm_loss         → clamp(maxiter) (only boost when
                                        behind — Alg. 1 line 12),
      4. else                         → clamp(round(variant formula)).

    ``jnp.round`` rounds half-to-even exactly like Python's ``round``,
    so the integer budgets agree with the host bitwise except on f32/f64
    knife edges of the ratio itself.
    """
    if variant not in regulation_mod.VARIANTS:
        raise ValueError(f"unknown variant {variant!r}; one of "
                         f"{regulation_mod.VARIANTS}")
    maxiter = jnp.asarray(maxiter, jnp.int32)
    q = jnp.asarray(qnn_loss, jnp.float32)
    llm = jnp.asarray(llm_loss, jnp.float32)
    m = maxiter.astype(jnp.float32)
    ratio = q / llm
    if variant == "adaptive":
        new = m * ratio
    elif variant == "incremental":
        new = m + increment * jnp.minimum(jnp.ceil(ratio), 5.0)
    elif variant == "logarithmic":
        new = m * (1.0 + jnp.log(ratio))
    else:  # dynamic
        new = (1 - weight) * m + weight * m * ratio
    boosted = jnp.clip(jnp.round(new), min_iter, cap).astype(jnp.int32)
    held = jnp.clip(maxiter, min_iter, cap)
    bad_llm = (llm <= 0) | ~jnp.isfinite(llm)
    bad_qnn = ~jnp.isfinite(q)
    behind = q > llm
    return jnp.where(bad_llm, maxiter,
                     jnp.where(bad_qnn | ~behind, held, boosted))


def select_topk_mask(dists, k):
    """Boolean mask form of ``selection.select_aligned``'s index list:
    True on the ``k`` smallest distances.  Non-finite distances harden
    to +inf (diverged clients sort last, never poison the sort), and
    ``jnp.argsort`` is stable, so ties resolve to the lower index —
    both exactly as in the host module.  ``k`` may be traced."""
    d = jnp.asarray(dists)
    d = jnp.where(jnp.isfinite(d), d, jnp.inf)
    order = jnp.argsort(d)                      # stable (jnp default)
    ranks = jnp.zeros_like(order).at[order].set(jnp.arange(d.shape[0]))
    return ranks < k


def termination_step(prev_loss, small, loss, t, *, epsilon: float,
                     t_max: int, patience: int = 1):
    """One round's transition of ``TerminationCriterion.update`` as a
    pure function: ``(prev_loss, small) × (loss, t) → (stop, small')``.

    Mirrors the host class exactly: ``t >= t_max`` stops *before* the
    patience counter updates (the host returns early, leaving ``_small``
    stale); with fewer than two recorded losses (``t < 2``) nothing is
    checked; a zero-loss plateau counts as converged while a fresh drop
    to exactly 0 counts as progress."""
    loss = jnp.asarray(loss, jnp.float32)
    prev_loss = jnp.asarray(prev_loss, jnp.float32)
    have_two = t >= 2
    nonzero = jnp.abs(loss) > 0
    rel = jnp.where(
        nonzero,
        jnp.abs(loss - prev_loss) / jnp.where(nonzero, jnp.abs(loss), 1.0),
        jnp.where(prev_loss == loss, jnp.float32(0.0), jnp.float32(jnp.inf)))
    small_new = jnp.where(have_two,
                          jnp.where(rel < epsilon, small + 1,
                                    jnp.zeros_like(small)),
                          small)
    at_cap = t >= t_max
    stop = at_cap | (have_two & (small_new >= patience))
    return stop, jnp.where(at_cap, small, small_new)


# ---------------------------------------------------------------------------
# the fused program
# ---------------------------------------------------------------------------
def _build_fused_program(spec, backend, *, lam, mu, use_llm, optimizer,
                         max_iter, regulation, maxiter_cap, select_frac,
                         epsilon, patience, n_rounds, early_stop, c_pop,
                         c_pad, c_round, dropout, mesh):
    cq = tape_mod.compile_qnn(spec)
    sampling = backend.shots > 0
    local_phase = build_local_phase(spec, backend, lam=lam, mu=mu,
                                    use_llm=use_llm, optimizer=optimizer,
                                    max_iter=max_iter)
    init_evals = 1 if optimizer == "spsa" else spec.n_params + 1
    subsample = c_round is not None
    c_width = int(c_round) if subsample else c_pad
    select_on = use_llm and select_frac < 1.0
    # top-k size: static whenever the per-round eligibility count is
    # static (no dropout) — then it is the host formula verbatim, in
    # float64.  With dropout the count is traced and k is computed in
    # f32 (knife-edge rounding of frac·n may differ from f64 — the
    # host reference mirrors the f32 form in that mode).
    k_static = None
    if select_on and dropout == 0.0:
        k_static = max(1, int(round(select_frac * (c_width if subsample
                                                   else c_pop))))

    def measure(theta, X, key):
        probs = tape_mod.tape_probs(cq, theta, X)
        if sampling:
            return backend.transform_probs(probs, key)
        return backend.apply_channel(probs)

    def report_one(theta, Xc, yc, mc, ckey):
        # on-device twin of orchestrator._nll at REPORT_EVAL_SLOT; the
        # masked mean equals nll_loss bitwise on a full (unpadded) shard
        noisy = measure(theta, Xc,
                        jax.random.fold_in(ckey,
                                           backend_mod.REPORT_EVAL_SLOT)
                        if sampling else None)
        p = jnp.take_along_axis(noisy, yc[:, None], axis=1)[:, 0]
        m_sum = jnp.maximum(jnp.sum(mc), 1.0)
        return -jnp.sum(jnp.log(p + 1e-9) * mc) / m_sum

    def program(theta0, budgets0, last0, cum0, qX, qy, mask, teacher,
                deltas, weights, evaltime, llm, val_qX, val_qy, test_qX,
                test_qy, base_key):

        is_real_pad = jnp.arange(c_pad) < c_pop

        def server_nll(theta, X, y, t, slot):
            key = (backend_mod.eval_key(base_key, t,
                                        backend_mod.SERVER_CLIENT, slot)
                   if sampling else None)
            return qnn.nll_loss(measure(theta, X, key), y)

        def server_acc(theta, X, y, t, slot):
            key = (backend_mod.eval_key(base_key, t,
                                        backend_mod.SERVER_CLIENT, slot)
                   if sampling else None)
            return qnn.accuracy(measure(theta, X, key), y)

        def body(carry, t):
            (theta_g, budgets, last_losses, cum_evals,
             prev_loss, small, done) = carry
            run = ~done

            # -- cohort ---------------------------------------------------
            if subsample:
                ck = backend_mod.eval_key(base_key, t,
                                          backend_mod.POP_CLIENT,
                                          backend_mod.POP_SLOT_COHORT)
                cohort = jnp.sort(jax.random.choice(
                    ck, c_pop, (c_width,), replace=False)).astype(jnp.int32)
                real = jnp.ones((c_width,), bool)
            else:
                cohort = jnp.arange(c_pad, dtype=jnp.int32)
                real = is_real_pad
            if dropout > 0.0:
                u = jax.vmap(lambda cid: jax.random.uniform(
                    backend_mod.eval_key(base_key, t, cid,
                                         backend_mod.DROPOUT_EVAL_SLOT)))(
                    cohort)
                dropped = (u < dropout) & real
            else:
                dropped = jnp.zeros((c_width,), bool)
            eligible = real & ~dropped

            # -- gather the cohort's rows --------------------------------
            if subsample:
                def g(a):
                    return shd.constrain_client_axis(
                        jnp.take(a, cohort, axis=0), mesh)
                gqX, gqy, gmask, gteacher = g(qX), g(qy), g(mask), g(teacher)
                gdeltas, gweights = g(deltas), g(weights)
                gevaltime, gllm = g(evaltime), g(llm)
                gbud0, glast = g(budgets), g(last_losses)
            else:
                gqX, gqy, gmask, gteacher = qX, qy, mask, teacher
                gdeltas, gweights, gevaltime, gllm = (deltas, weights,
                                                      evaltime, llm)
                gbud0, glast = budgets, last_losses

            # -- regulation (Alg. 1 lines 11-17; after round 1 only) ------
            if use_llm:
                boosted = regulate_batched(gbud0, glast, gllm,
                                           variant=regulation,
                                           cap=maxiter_cap)
                gbud = jnp.where((t > 1) & eligible, boosted, gbud0)
                gratios = jnp.where(
                    (t > 1) & jnp.isfinite(glast) & (gllm > 0.0),
                    glast / gllm, jnp.float32(1.0))
            else:
                gbud = gbud0
                gratios = jnp.ones((c_width,), jnp.float32)

            # -- local phase: the engine's traceable body -----------------
            rk = jax.random.fold_in(base_key, t)
            ckeys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(rk,
                                                                    cohort)
            th, n_evals = local_phase(gqX, gqy, gmask, gteacher, theta_g,
                                      gbud, ckeys, deltas=gdeltas,
                                      active=eligible)

            # -- report F_i from the carry (no host loop) -----------------
            glosses = jax.vmap(report_one)(th, gqX, gqy, gmask, ckeys)
            glosses = jnp.where(eligible, glosses, jnp.nan)

            s_pre = server_nll(theta_g, val_qX, val_qy, t,
                               backend_mod.SERVER_SLOT_LOSS_PRE)

            # -- alignment selection (Sec. III-B) -------------------------
            if select_on:
                d = jnp.abs(glosses - s_pre)
                d = jnp.where(jnp.isfinite(d) & eligible, d, jnp.inf)
                if k_static is not None:
                    k = k_static
                else:
                    n_el = jnp.sum(eligible).astype(jnp.float32)
                    k = jnp.maximum(
                        1, jnp.round(select_frac * n_el)).astype(jnp.int32)
                sel = select_topk_mask(d, k) & eligible
            else:
                sel = eligible

            # -- FedAvg (Eq. 3) over the selected set ---------------------
            w = jnp.where(sel, gweights, 0.0)
            wsum = jnp.sum(w)
            theta_new = jnp.sum(
                (w / jnp.maximum(wsum, 1e-30))[:, None] * th, axis=0)
            theta_new = jnp.where(wsum > 0, theta_new, theta_g)
            theta_g = jnp.where(run, theta_new, theta_g)

            s_post = server_nll(theta_g, val_qX, val_qy, t,
                                backend_mod.SERVER_SLOT_LOSS_POST)
            v_acc = server_acc(theta_g, val_qX, val_qy, t,
                               backend_mod.SERVER_SLOT_VAL_ACC)
            t_acc = server_acc(theta_g, test_qX, test_qy, t,
                               backend_mod.SERVER_SLOT_TEST_ACC)

            # -- termination ---------------------------------------------
            stop, small_new = termination_step(
                prev_loss, small, s_post, t, epsilon=epsilon,
                t_max=n_rounds, patience=patience)
            prev_loss = jnp.where(run, s_post, prev_loss)
            small = jnp.where(run, small_new, small)
            if early_stop:
                done_next = done | (run & stop)
            else:
                done_next = done

            # -- scatter cohort state back to the population carries ------
            upd = run & eligible
            evals_add = jnp.where(upd, n_evals, 0)
            if subsample:
                budgets = budgets.at[cohort].set(
                    jnp.where(upd, gbud, gbud0))
                last_losses = last_losses.at[cohort].set(
                    jnp.where(upd, glosses, glast))
                cum_evals = cum_evals.at[cohort].add(evals_add)
            else:
                budgets = jnp.where(upd, gbud, budgets)
                last_losses = jnp.where(upd, glosses, last_losses)
                cum_evals = cum_evals + evals_add
            if mesh is not None:
                # full participation: the carries ARE the sharded client
                # stacks.  Population mode: carries stay replicated (the
                # scatter of sharded cohort values must not let GSPMD
                # drift the carry sharding between scan iterations).
                pin = (shd.constrain_replicated if subsample
                       else shd.constrain_client_axis)
                budgets = pin(budgets, mesh)
                last_losses = pin(last_losses, mesh)
                cum_evals = pin(cum_evals, mesh)

            comm = jnp.max(jnp.where(
                eligible,
                gevaltime * (n_evals - init_evals).astype(jnp.float32),
                0.0))
            comm = jnp.where(run, comm, 0.0)

            ys = dict(active=run, stop=run & stop, cohort=cohort,
                      dropped=dropped, selected=sel, losses=glosses,
                      ratios=gratios, n_evals=evals_add,
                      budgets=budgets, cum_evals=cum_evals,
                      server_loss_pre=s_pre, server_loss=s_post,
                      val_acc=v_acc, test_acc=t_acc, comm_time_s=comm,
                      theta=theta_g)
            carry = (theta_g, budgets, last_losses, cum_evals,
                     prev_loss, small, done_next)
            return carry, ys

        carry0 = (jnp.asarray(theta0, jnp.float32), budgets0, last0, cum0,
                  jnp.float32(jnp.nan), jnp.int32(0),
                  jnp.asarray(False))
        ts = jnp.arange(1, n_rounds + 1, dtype=jnp.int32)
        carry, ys = jax.lax.scan(body, carry0, ts)
        ys["theta_g"] = carry[0]
        ys["budgets_final"] = carry[1]
        ys["last_losses_final"] = carry[2]
        ys["cum_evals_final"] = carry[3]
        return ys

    return jax.jit(program)


def get_fused_program(spec, backend, *, lam, mu, use_llm, optimizer,
                      max_iter, regulation, maxiter_cap, select_frac,
                      epsilon, patience, n_rounds, early_stop, c_pop,
                      c_pad, c_round, dropout, mesh):
    """Module-wide cache, like ``batched_engine.get_round_fn``: fresh
    driver instances with the same static config reuse the compiled
    scan (population stacks and θ_g are traced arguments)."""
    mesh_key = (None if mesh is None
                else tuple(int(d.id) for d in mesh.devices.flat))
    key = (spec, backend, int(backend.shots), float(lam), float(mu),
           bool(use_llm), optimizer, int(max_iter), regulation,
           int(maxiter_cap), float(select_frac), float(epsilon),
           int(patience), int(n_rounds), bool(early_stop), int(c_pop),
           int(c_pad), None if c_round is None else int(c_round),
           float(dropout), mesh_key)
    if key not in _FUSED_CACHE:
        _FUSED_CACHE[key] = _build_fused_program(
            spec, backend, lam=lam, mu=mu, use_llm=use_llm,
            optimizer=optimizer, max_iter=max_iter, regulation=regulation,
            maxiter_cap=maxiter_cap, select_frac=select_frac,
            epsilon=epsilon, patience=patience, n_rounds=n_rounds,
            early_stop=early_stop, c_pop=c_pop, c_pad=c_pad,
            c_round=c_round, dropout=dropout, mesh=mesh)
    return _FUSED_CACHE[key]


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
@dataclass
class FusedRunOutput:
    """Per-round arrays over the full R scheduled rounds (rows past the
    termination round have ``active=False`` and frozen/zero payloads)
    plus the final population carries.  ``c_width`` is the cohort array
    length — ``c_round`` in population mode, the padded client count
    under full participation."""
    active: np.ndarray            # (R,)  bool — round executed
    stop: np.ndarray              # (R,)  bool — termination fired here
    cohort: np.ndarray            # (R, c_width) int32 population ids
    dropped: np.ndarray           # (R, c_width) bool
    selected: np.ndarray          # (R, c_width) bool (cohort positions)
    losses: np.ndarray            # (R, c_width) reported F_i (NaN if out)
    ratios: np.ndarray            # (R, c_width) regulation ratios
    n_evals: np.ndarray           # (R, c_width) this round's eval spend
    budgets: np.ndarray           # (R, c_pad) post-regulation budgets
    cum_evals: np.ndarray         # (R, c_pad)
    server_loss_pre: np.ndarray   # (R,)
    server_loss: np.ndarray       # (R,)
    val_acc: np.ndarray           # (R,)
    test_acc: np.ndarray          # (R,)
    comm_time_s: np.ndarray       # (R,)
    theta: np.ndarray             # (R, P) θ_g after each round
    theta_g: np.ndarray           # (P,)  final global parameters
    budgets_final: np.ndarray     # (c_pad,)
    last_losses_final: np.ndarray  # (c_pad,)
    cum_evals_final: np.ndarray   # (c_pad,)

    @property
    def stop_round(self) -> Optional[int]:
        """1-based round where termination fired, or None."""
        hit = np.nonzero(self.stop & self.active)[0]
        return int(hit[0]) + 1 if hit.size else None

    @property
    def n_active(self) -> int:
        return int(np.sum(self.active))


class FusedRoundDriver:
    """Stacks the population once; runs R federated rounds per call."""

    def __init__(self, task, spec, backend, *, optimizer: str = "nelder-mead",
                 seed: int = 0, lam: float = 0.1, mu: float = 0.01,
                 use_llm: bool = False, teacher_probs: Optional[List] = None,
                 llm_losses: Optional[Sequence[float]] = None,
                 maxiter0: int = 10, maxiter_cap: int = 100,
                 regulation: str = "adaptive", select_frac: float = 1.0,
                 epsilon: float = 1e-3, n_rounds: int = 10,
                 early_stop: bool = True, patience: int = 1,
                 c_round: Optional[int] = None, dropout: float = 0.0,
                 n_devices: Optional[int] = None):
        C = task.n_clients
        if c_round is not None:
            c_round = int(c_round)
            if not 1 <= c_round <= C:
                raise ValueError(
                    f"c_round={c_round} must be in [1, C_pop={C}]")
            if c_round == C:
                c_round = None            # full participation
        if not 0.0 <= dropout < 1.0:
            raise ValueError(f"dropout={dropout} must be in [0, 1)")
        if use_llm and (teacher_probs is None or llm_losses is None):
            raise ValueError("use_llm=True needs teacher_probs and "
                             "llm_losses from the LLM fine-tuning stage")

        self._mesh = None
        c_pad = C
        if n_devices is not None and int(n_devices) > 1:
            self._mesh = shd.client_mesh(int(n_devices))
            c_pad = shd.pad_client_count(C, int(n_devices))
            if c_round is not None:
                # the gathered cohort is what shards per round — it must
                # divide the mesh (no padding inside the scan body)
                shd.check_client_divisibility(c_round, int(n_devices))

        n_cls = task.n_classes
        b_max = max(cl.n for cl in task.clients)
        qX = np.zeros((c_pad, b_max, spec.n_qubits), np.float32)
        qy = np.zeros((c_pad, b_max), np.int32)
        mask = np.zeros((c_pad, b_max), np.float32)
        teacher = np.full((c_pad, b_max, n_cls), 1.0 / n_cls, np.float32)
        for i, cl in enumerate(task.clients):
            qX[i, :cl.n] = cl.qX
            qy[i, :cl.n] = cl.qy
            mask[i, :cl.n] = 1.0
            if teacher_probs is not None and teacher_probs[i] is not None:
                teacher[i, :cl.n] = np.asarray(teacher_probs[i], np.float32)

        # same budget-record width rule as the orchestrator's engine:
        # regulation can boost budgets up to the cap; without the LLM
        # they stay at maxiter0 (SPSA ignores unused delta rows and NM's
        # loop bound is min(max(iters), max_iter), so a wider record is
        # behavior-identical — just wasted delta memory)
        max_iter = max(maxiter_cap, maxiter0) if use_llm else maxiter0
        if optimizer == "spsa":
            deltas = np.ones((c_pad, max_iter, spec.n_params), np.float64)
            deltas[:C] = make_deltas([seed * 997 + i for i in range(C)],
                                     max_iter, spec.n_params)
            self._deltas = jnp.asarray(deltas, jnp.float32)
        else:
            self._deltas = jnp.zeros((c_pad, 1, 1), jnp.float32)

        weights = np.zeros((c_pad,), np.float32)
        weights[:C] = np.asarray(task.weights, np.float32)
        evaltime = np.zeros((c_pad,), np.float32)
        evaltime[:C] = [backend.eval_time(cl.n) for cl in task.clients]
        llm = np.zeros((c_pad,), np.float32)
        if llm_losses is not None:
            llm[:C] = np.asarray(llm_losses, np.float32)
        budgets0 = np.zeros((c_pad,), np.int32)
        budgets0[:C] = int(maxiter0)
        last0 = np.full((c_pad,), np.inf, np.float32)
        cum0 = np.zeros((c_pad,), np.int32)

        self._qX, self._qy = jnp.asarray(qX), jnp.asarray(qy)
        self._mask = jnp.asarray(mask)
        self._teacher = jnp.asarray(teacher)
        self._weights = jnp.asarray(weights)
        self._evaltime = jnp.asarray(evaltime)
        self._llm = jnp.asarray(llm)
        self._budgets0 = jnp.asarray(budgets0)
        self._last0 = jnp.asarray(last0)
        self._cum0 = jnp.asarray(cum0)
        self._val_qX = jnp.asarray(task.val_qX, jnp.float32)
        self._val_qy = jnp.asarray(task.val_qy, jnp.int32)
        self._test_qX = jnp.asarray(task.test_qX, jnp.float32)
        self._test_qy = jnp.asarray(task.test_qy, jnp.int32)
        self._base_key = jax.random.PRNGKey(seed)

        if self._mesh is not None:
            stacks = (self._qX, self._qy, self._mask, self._teacher,
                      self._deltas, self._weights, self._evaltime,
                      self._llm, self._budgets0, self._last0, self._cum0)
            if c_round is not None:
                # population mode: REPLICATE the population state and
                # shard only the gathered per-round cohort (the compute).
                # Sharding the (C_pop, …) stacks makes every round's
                # dynamic cohort gather and carry scatter a cross-device
                # collective chain inside the scan, which costs more
                # than the round itself (bench_population measured the
                # sharded-stack layout at 0.84× the host loop; the
                # replicated layout beats it).  Full participation keeps
                # the sharded stacks — there the stacks ARE the round.
                placed = tuple(shd.put_replicated(self._mesh, a)
                               for a in stacks)
            else:
                placed = shd.put_client_stacks(self._mesh, stacks, c_pad)
            (self._qX, self._qy, self._mask, self._teacher, self._deltas,
             self._weights, self._evaltime, self._llm, self._budgets0,
             self._last0, self._cum0) = placed
            (self._val_qX, self._val_qy, self._test_qX,
             self._test_qy) = (shd.put_replicated(self._mesh, a)
                               for a in (self._val_qX, self._val_qy,
                                         self._test_qX, self._test_qy))

        self.task, self.spec, self.backend = task, spec, backend
        self.c_pop, self.c_pad, self.c_round = C, c_pad, c_round
        self.c_width = c_round if c_round is not None else c_pad
        self.dropout, self.seed = float(dropout), int(seed)
        self.optimizer, self.max_iter = optimizer, max_iter
        self.use_llm, self.n_rounds = use_llm, int(n_rounds)
        self.init_evals = 1 if optimizer == "spsa" else spec.n_params + 1
        self._cfg = dict(
            lam=lam, mu=mu, use_llm=use_llm, optimizer=optimizer,
            max_iter=max_iter, regulation=regulation,
            maxiter_cap=maxiter_cap, select_frac=select_frac,
            epsilon=epsilon, patience=patience, n_rounds=int(n_rounds),
            early_stop=early_stop, c_pop=C, c_pad=c_pad, c_round=c_round,
            dropout=float(dropout))
        self._program = get_fused_program(spec, backend, mesh=self._mesh,
                                          **self._cfg)
        self._fwd = None          # host-reference lazies
        self._local_jit = None

    # -- fused path ---------------------------------------------------------
    def run(self, theta_g) -> FusedRunOutput:
        """All R rounds as one program execution; one device→host
        transfer for the whole run's outputs."""
        th = jnp.asarray(theta_g, jnp.float32)
        if self._mesh is not None:
            th = shd.put_replicated(self._mesh, th)
        out = self._program(th, self._budgets0, self._last0, self._cum0,
                            self._qX, self._qy, self._mask, self._teacher,
                            self._deltas, self._weights, self._evaltime,
                            self._llm, self._val_qX, self._val_qy,
                            self._test_qX, self._test_qy, self._base_key)
        host = jax.device_get(out)
        return FusedRunOutput(**{k: np.asarray(v) for k, v in host.items()})

    # -- host-reference path (the per-round loop baseline / oracle) ---------
    def _host_round_pieces(self):
        if self._local_jit is None:
            lp = build_local_phase(
                self.spec, self.backend, lam=self._cfg["lam"],
                mu=self._cfg["mu"], use_llm=self.use_llm,
                optimizer=self.optimizer, max_iter=self.max_iter)
            self._local_jit = jax.jit(
                lambda qX, qy, mask, teacher, thg, iters, ckeys, deltas,
                active: lp(qX, qy, mask, teacher, thg, iters, ckeys,
                           deltas=deltas, active=active))
            self._fwd = tape_mod.make_tape_forward(self.spec)
        return self._local_jit, self._fwd

    def run_host_reference(self, theta_g) -> FusedRunOutput:
        """The status-quo per-round host loop over the same population
        semantics: one jitted program per round for the local phase, but
        regulation / selection / aggregation / termination on host via
        the reference modules (``regulation.regulate``, the stable-sort
        selection rule, ``TerminationCriterion``, float64 FedAvg) and
        the orchestrator-style per-client report evals (one device→host
        transfer per client per round).  The fused program must match
        it round-for-round; ``bench_population`` times it as the
        baseline."""
        cfg = self._cfg
        local, fwd = self._host_round_pieces()
        sampling = self.backend.shots > 0
        base = self._base_key
        C, c_pad, c_width = self.c_pop, self.c_pad, self.c_width
        R = self.n_rounds
        subsample = self.c_round is not None
        select_on = self.use_llm and cfg["select_frac"] < 1.0

        qX = np.asarray(self._qX)
        qy = np.asarray(self._qy)
        mask = np.asarray(self._mask)
        teacher = np.asarray(self._teacher)
        deltas = np.asarray(self._deltas)
        weights = np.asarray(self._weights, np.float64)
        evaltime = np.asarray(self._evaltime, np.float64)
        llm = np.asarray(self._llm)

        theta = np.asarray(theta_g, np.float64)
        budgets = np.asarray(self._budgets0).copy()
        last = np.asarray(self._last0).copy()
        cum = np.asarray(self._cum0).copy()
        term = TerminationCriterion(epsilon=cfg["epsilon"], t_max=R,
                                    patience=cfg["patience"])

        def znan(shape):
            return np.full(shape, np.nan, np.float32)

        out = dict(
            active=np.zeros(R, bool), stop=np.zeros(R, bool),
            cohort=np.zeros((R, c_width), np.int32),
            dropped=np.zeros((R, c_width), bool),
            selected=np.zeros((R, c_width), bool),
            losses=znan((R, c_width)), ratios=np.ones((R, c_width),
                                                      np.float32),
            n_evals=np.zeros((R, c_width), np.int32),
            budgets=np.zeros((R, c_pad), np.int32),
            cum_evals=np.zeros((R, c_pad), np.int32),
            server_loss_pre=znan(R), server_loss=znan(R), val_acc=znan(R),
            test_acc=znan(R), comm_time_s=np.zeros(R, np.float32),
            theta=np.zeros((R, theta.size), np.float64))

        def nll_host(th, X, y, t, client, slot):
            probs = fwd(jnp.asarray(th, jnp.float32), jnp.asarray(X))
            key = (backend_mod.eval_key(base, t, client, slot)
                   if sampling else None)
            probs = self.backend.transform_probs(probs, key) \
                if sampling else self.backend.apply_channel(probs)
            return float(qnn.nll_loss(probs, jnp.asarray(y)))

        def acc_host(th, X, y, t, slot):
            probs = fwd(jnp.asarray(th, jnp.float32), jnp.asarray(X))
            key = (backend_mod.eval_key(base, t,
                                        backend_mod.SERVER_CLIENT, slot)
                   if sampling else None)
            probs = self.backend.transform_probs(probs, key) \
                if sampling else self.backend.apply_channel(probs)
            return float(qnn.accuracy(probs, jnp.asarray(y)))

        for r in range(R):
            t = r + 1
            if subsample:
                ck = backend_mod.eval_key(base, t, backend_mod.POP_CLIENT,
                                          backend_mod.POP_SLOT_COHORT)
                cohort = np.sort(np.asarray(jax.random.choice(
                    ck, C, (c_width,), replace=False))).astype(np.int32)
                real = np.ones(c_width, bool)
            else:
                cohort = np.arange(c_pad, dtype=np.int32)
                real = cohort < C
            if self.dropout > 0.0:
                u = np.asarray([float(jax.random.uniform(
                    backend_mod.eval_key(base, t, int(cid),
                                         backend_mod.DROPOUT_EVAL_SLOT)))
                    for cid in cohort])
                dropped = (u < self.dropout) & real
            else:
                dropped = np.zeros(c_width, bool)
            eligible = real & ~dropped

            gbud = budgets[cohort].copy()
            if self.use_llm and t > 1:
                for p in np.nonzero(eligible)[0]:
                    cid = int(cohort[p])
                    gbud[p] = regulation_mod.regulate(
                        int(gbud[p]), float(last[cid]), float(llm[cid]),
                        variant=cfg["regulation"], cap=cfg["maxiter_cap"])
            ratios = np.ones(c_width, np.float32)
            if self.use_llm and t > 1:
                fin = np.isfinite(last[cohort]) & (llm[cohort] > 0)
                with np.errstate(invalid="ignore"):
                    ratios = np.where(fin, last[cohort] / llm[cohort],
                                      1.0).astype(np.float32)

            rk = jax.random.fold_in(base, t)
            ckeys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
                rk, jnp.asarray(cohort))
            th_stack, n_evals = local(
                jnp.asarray(qX[cohort]), jnp.asarray(qy[cohort]),
                jnp.asarray(mask[cohort]), jnp.asarray(teacher[cohort]),
                jnp.asarray(theta, jnp.float32), jnp.asarray(gbud),
                ckeys, jnp.asarray(deltas[cohort]), jnp.asarray(eligible))
            th_stack = np.asarray(th_stack, np.float64)
            n_evals = np.asarray(n_evals, np.int32)

            # orchestrator-style reporting: one transfer per client
            losses = np.full(c_width, np.nan, np.float32)
            for p in np.nonzero(eligible)[0]:
                cid = int(cohort[p])
                cl = self.task.clients[cid]
                losses[p] = nll_host(th_stack[p], cl.qX, cl.qy, t, cid,
                                     backend_mod.REPORT_EVAL_SLOT)

            s_pre = nll_host(theta, self.task.val_qX, self.task.val_qy, t,
                             backend_mod.SERVER_CLIENT,
                             backend_mod.SERVER_SLOT_LOSS_PRE)

            if select_on:
                with np.errstate(invalid="ignore"):
                    d = np.abs(losses.astype(np.float64) - s_pre)
                d = np.where(np.isfinite(d) & eligible, d, np.inf)
                n_el = int(np.sum(eligible))
                if self.dropout > 0.0:
                    # mirror the fused program's traced-k f32 form
                    k = int(max(1, np.round(np.float32(cfg["select_frac"])
                                            * np.float32(n_el))))
                else:
                    k = max(1, int(round(cfg["select_frac"]
                                         * (c_width if subsample else C))))
                order = np.argsort(d, kind="stable")[:k]
                sel = np.zeros(c_width, bool)
                sel[order] = True
                sel &= eligible
            else:
                sel = eligible.copy()

            w = np.where(sel, weights[cohort], 0.0)
            if w.sum() > 0:
                wn = w / w.sum()
                theta = sum(wn[p] * th_stack[p]
                            for p in np.nonzero(sel)[0])

            s_post = nll_host(theta, self.task.val_qX, self.task.val_qy,
                              t, backend_mod.SERVER_CLIENT,
                              backend_mod.SERVER_SLOT_LOSS_POST)
            v_acc = acc_host(theta, self.task.val_qX, self.task.val_qy, t,
                             backend_mod.SERVER_SLOT_VAL_ACC)
            t_acc = acc_host(theta, self.task.test_qX, self.task.test_qy,
                             t, backend_mod.SERVER_SLOT_TEST_ACC)

            upd = eligible
            budgets[cohort[upd]] = gbud[upd]
            last[cohort[upd]] = losses[upd]
            cum[cohort[upd]] += n_evals[upd]
            comm = float(np.max(np.where(
                eligible, evaltime[cohort] * (n_evals - self.init_evals),
                0.0), initial=0.0))

            out["active"][r] = True
            out["cohort"][r] = cohort
            out["dropped"][r] = dropped
            out["selected"][r] = sel
            out["losses"][r] = losses
            out["ratios"][r] = ratios
            out["n_evals"][r] = np.where(upd, n_evals, 0)
            out["budgets"][r] = budgets
            out["cum_evals"][r] = cum
            out["server_loss_pre"][r] = s_pre
            out["server_loss"][r] = s_post
            out["val_acc"][r] = v_acc
            out["test_acc"][r] = t_acc
            out["comm_time_s"][r] = comm
            out["theta"][r] = theta

            if term.update(s_post, t):
                out["stop"][r] = True
                if cfg["early_stop"]:
                    break

        return FusedRunOutput(theta_g=np.asarray(theta, np.float32),
                              budgets_final=budgets.copy(),
                              last_losses_final=last.copy(),
                              cum_evals_final=cum.copy(), **out)
