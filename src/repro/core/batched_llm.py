"""Batched LLM fine-tuning engine — Alg. 1 Step 1 as one device program.

The sequential reference (``core/llm_client.LLMClient`` driven by the
orchestrator) fine-tunes clients one at a time: ``llm_steps`` host
dispatches per client, then per-client host evals and a host-side
adapter blend.  This engine runs the **entire fine-tuning stage** — all
C clients' LoRA adapters, every optimizer step, the FedAvg teacher, the
distillation blend, and the label-head evaluations — as a single jitted
program:

  - adapters and AdamW states are stacked into leading-axis ``(C, …)``
    pytrees (``jax.vmap(M.init_adapters)`` / ``jax.vmap(adamw.init)``),
  - the **single shared frozen base is replicated, never stacked** —
    the vmapped train step takes it with ``in_axes=None``,
  - fine-tuning is ``lax.scan`` over ``llm_steps`` of
    ``jax.vmap(M.make_train_step(cfg), in_axes=(None, 0, 0, 0))``,
  - per-client minibatches draw under the ``llm_client.llm_key(root,
    client, step)`` contract via ``sample_minibatch_idx`` — bitwise the
    sequential draws, so batched == sequential draw-for-draw,
  - ``fedavg_adapters`` + ``distill_to_global`` become an on-device
    masked weighted tree reduction
    (``lora.weighted_average_stacked`` + ``lora.blend_adapters``),
  - ``eval_loss`` / ``teacher_probs`` / ``f1`` run as vmapped masked
    label-head evals on the blended adapters.

Padding/mask contract (PR-4 style, two explicit layers)
-------------------------------------------------------
Client shards are ragged in *examples*, and the client count can be
ragged against the device mesh:

  - **example axis**: each client's token shard is padded to
    ``(Nmax, L)`` — tokens with PAD, labels with -1 (so no row mask is
    inferred from content: ``rowmask`` (C, Nmax) is explicit, 1.0 on
    real examples).  Evaluations are mask-weighted with the denominator
    clamped to 1; training minibatches index only rows ``< n_i`` so
    padding never enters the loss.
  - **client axis**: with ``n_devices > 1`` the stacks are padded to a
    multiple of the mesh width (``sharding.pad_client_count``) with
    inert clients — all-zero rowmasks, shard size clamped to 1, zero
    FedAvg weight, PAD-token shards whose all-masked CE is 0, so their
    gradients and AdamW updates are exactly zero.  Padding rows take
    client ids ``C..c_pad-1`` *after* every real client (key folding is
    position-based — sharding never renumbers a real client's draws).

Sharding
--------
With ``n_devices > 1`` the stacks are placed along the 1-D ``'clients'``
mesh (``sharding.put_client_stacks``; adapter/AdamW pytrees via the
strict ``client_tree_specs``) and the base/weights replicated
(``put_replicated``).  GSPMD partitions the jitted program by
computation-follows-data.  Unlike the quantum round program, this
program contains **one deliberate cross-client reduction** — the FedAvg
teacher ``a_g = Σ w_i a_i`` at the distill point — which lowers to a
single all-reduce over adapter-sized tensors; everything before
(fine-tune scan) and after (evals) is collective-free along the client
axis.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import llm_client as llmc
from repro.data.tokenizer import PAD
from repro.distributed import sharding as shd
from repro.models import model as M
from repro.optim import adamw
from repro.peft import lora as lora_mod

_LLM_ROUND_CACHE: Dict[tuple, object] = {}


@dataclasses.dataclass
class LLMRoundResult:
    """Per-client outputs of the fine-tuning stage (real clients only)."""
    losses: np.ndarray            # (C,)  post-distill eval NLL (L_LLM)
    f1: np.ndarray                # (C,)  post-distill macro-F1
    teacher: np.ndarray           # (C, Nmax, n_labels) soft labels
    final_train_loss: np.ndarray  # (C,)  last fine-tune minibatch loss


def _build_llm_round_fn(cfg, n_labels: int, lr: float, batch_size: int,
                        steps: int, rho: float):
    """Jitted fine-tuning stage → (adapters, opt, a_g, losses, f1,
    teacher, last_train_loss).  Static config closed over; every
    per-round quantity (stacks, keys, weights) is a traced input."""
    train_step = M.make_train_step(cfg, n_microbatches=1, lr=lr,
                                   opts=M.FwdOptions(remat=False))
    vstep = jax.vmap(train_step, in_axes=(None, 0, 0, 0))

    def eval_one(params, adp, toks, labs, rmask):
        logits, gold = llmc.label_logits(cfg, params, adp, toks, labs,
                                         n_labels)
        loss = llmc.masked_label_nll(logits, gold, rmask)
        f1 = llmc.masked_macro_f1(logits, gold, rmask, n_labels)
        return loss, f1, jax.nn.softmax(logits, axis=-1)

    veval = jax.vmap(eval_one, in_axes=(None, 0, 0, 0, 0))

    @jax.jit
    def round_fn(base, adapters, opt_state, tokens, labels, rowmask,
                 nvalid, weights, ckeys, step0):
        def body(carry, s):
            adp, opt = carry
            keys = jax.vmap(jax.random.fold_in, in_axes=(0, None))(
                ckeys, s)
            idx = jax.vmap(llmc.sample_minibatch_idx,
                           in_axes=(0, 0, None))(keys, nvalid, batch_size)
            mb = {"tokens": jax.vmap(lambda t, i: t[i])(tokens, idx),
                  "labels": jax.vmap(lambda t, i: t[i])(labels, idx)}
            adp, opt, metrics = vstep(base, adp, opt, mb)
            return (adp, opt), metrics["loss"]

        # step0 is the GLOBAL step offset (traced — a refresh does not
        # recompile): the contract's ``step`` keeps counting across
        # run() calls, like the sequential wrapper's ``_n_steps``
        (adapters, opt_state), tlosses = jax.lax.scan(
            body, (adapters, opt_state), step0 + jnp.arange(steps))
        # Alg. 1 line 8 on device: FedAvg teacher (the one cross-client
        # reduction of this program) + distillation blend
        a_g = lora_mod.weighted_average_stacked(adapters, weights)
        adapters = lora_mod.blend_adapters(adapters, a_g, rho)
        losses, f1s, teacher = veval(base, adapters, tokens, labels,
                                     rowmask)
        return adapters, opt_state, a_g, losses, f1s, teacher, tlosses[-1]

    return round_fn


def get_llm_round_fn(cfg, *, n_labels: int, lr: float, batch_size: int,
                     steps: int, rho: float):
    """Module-cached program: fresh engine instances (new runs, tests,
    benches) with the same static config reuse one compilation; jax's
    cache then specializes per stack shape."""
    key = (cfg, int(n_labels), float(lr), int(batch_size), int(steps),
           float(rho))
    if key not in _LLM_ROUND_CACHE:
        _LLM_ROUND_CACHE[key] = _build_llm_round_fn(
            cfg, n_labels, lr, batch_size, steps, rho)
    return _LLM_ROUND_CACHE[key]


class BatchedLLMEngine:
    """Stacks all clients' shards/adapters once; runs the stage on device."""

    def __init__(self, task, cfg, base_params, *, seed: int,
                 lr: float = 3e-3, steps: int = 30, batch_size: int = 16,
                 rho: float = 0.25, n_devices: Optional[int] = None,
                 pad_to: Optional[int] = None):
        C = task.n_clients
        n_labels = task.n_classes
        n_max = max(cl.n for cl in task.clients)
        L = task.llm_seq_len

        # ``pad_to`` pads the client axis without a mesh — mesh placement
        # does this automatically; exposed so the padding-inertness
        # contract is testable on a single device.
        self._mesh = None
        c_pad = max(C, int(pad_to)) if pad_to else C
        if n_devices is not None and int(n_devices) > 1:
            self._mesh = shd.client_mesh(int(n_devices))
            c_pad = shd.pad_client_count(c_pad, int(n_devices))

        tokens = np.full((c_pad, n_max, L), PAD, np.int32)
        labels = np.full((c_pad, n_max, L), -1, np.int32)
        rowmask = np.zeros((c_pad, n_max), np.float32)
        nvalid = np.ones((c_pad,), np.int32)     # clamped: padding → 1
        weights = np.zeros((c_pad,), np.float32)
        for i, cl in enumerate(task.clients):
            tokens[i, :cl.n] = cl.llm_batch["tokens"]
            labels[i, :cl.n] = cl.llm_batch["labels"]
            rowmask[i, :cl.n] = 1.0
            nvalid[i] = cl.n
            weights[i] = task.weights[i]
        self._tokens = jnp.asarray(tokens)
        self._labels = jnp.asarray(labels)
        self._rowmask = jnp.asarray(rowmask)
        self._nvalid = jnp.asarray(nvalid)
        self._weights = jnp.asarray(weights)

        # contract keys: real clients keep positions 0..C-1, padding
        # rows fold ids C..c_pad-1 after them (never renumbered)
        root = llmc.llm_root(seed)
        cids = jnp.arange(c_pad)
        self._ckeys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
            root, cids)
        ikeys = jax.vmap(llmc.llm_key, in_axes=(None, 0, None))(
            root, cids, llmc.LLM_INIT_STEP)
        self._base = base_params
        self.adapters = jax.vmap(
            lambda k: M.init_adapters(cfg, k, base_params))(ikeys)
        self.opt_state = jax.vmap(adamw.init)(self.adapters)

        if self._mesh is not None:
            flat = (self._tokens, self._labels, self._rowmask,
                    self._nvalid, self._weights, self._ckeys)
            (self._tokens, self._labels, self._rowmask, self._nvalid,
             self._weights, self._ckeys) = shd.put_client_stacks(
                self._mesh, flat, c_pad)
            # adapter/AdamW pytrees: every leaf must be client-stacked —
            # the strict tree placement catches a forgotten vmap(init)
            self.adapters = shd.put_client_tree(self._mesh, self.adapters,
                                                c_pad)
            self.opt_state = shd.put_client_tree(self._mesh,
                                                 self.opt_state, c_pad)
            # the frozen base is REPLICATED, never stacked: its leaves'
            # leading dims (vocab, groups) must not be sharded even if
            # one coincidentally equals c_pad
            self._base = shd.put_replicated(self._mesh, self._base)

        self._n_clients = C
        self._c_pad = c_pad
        self._steps = int(steps)
        self._n_steps = 0             # global step counter (key contract)
        self._round = get_llm_round_fn(cfg, n_labels=n_labels, lr=lr,
                                       batch_size=batch_size, steps=steps,
                                       rho=rho)

    def run(self) -> LLMRoundResult:
        """Fine-tune all clients, distill toward the FedAvg teacher, and
        evaluate — one device program.  Updates the engine's stacked
        adapter/optimizer state and advances the global step counter, so
        a later refresh continues from both (draws resume at step
        ``_n_steps``, matching the sequential wrapper's counter)."""
        (self.adapters, self.opt_state, self.a_g, losses, f1s, teacher,
         tlast) = self._round(self._base, self.adapters, self.opt_state,
                              self._tokens, self._labels, self._rowmask,
                              self._nvalid, self._weights, self._ckeys,
                              jnp.int32(self._n_steps))
        self._n_steps += self._steps
        C = self._n_clients
        return LLMRoundResult(
            losses=np.asarray(losses, np.float64)[:C],
            f1=np.asarray(f1s, np.float64)[:C],
            teacher=np.asarray(teacher, np.float32)[:C],
            final_train_loss=np.asarray(tlast, np.float64)[:C])

    def teacher_probs_list(self, task, teacher: np.ndarray) -> List:
        """Slice the padded (C, Nmax, n_labels) teacher stack back into
        the orchestrator's ragged per-client list."""
        return [teacher[i, :cl.n] for i, cl in enumerate(task.clients)]
