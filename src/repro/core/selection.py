"""Alignment-based client selection (Sec. III-B).

d_i^t = |L_i^t − L_s^t|; keep the devices with the smallest k% distances.
Reduces aggregation variance by (1 − k/N) (Cor. VI.8.2).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def distances(client_losses: Sequence[float], server_loss: float
              ) -> np.ndarray:
    """|L_i − L_s| with non-finite entries mapped to +inf: a diverged
    client (NaN/inf local loss) is maximally misaligned — it sorts last
    in selection and never contaminates downstream statistics with NaN
    (NaN would also break ``argsort``'s ordering guarantees)."""
    with np.errstate(invalid="ignore"):
        d = np.abs(np.asarray(client_losses, np.float64) - server_loss)
    return np.where(np.isfinite(d), d, np.inf)


def select_aligned(client_losses: Sequence[float], server_loss: float,
                   frac: float) -> List[int]:
    """Indices of the top-k% most aligned clients (ties → lower index).
    Always returns at least one client; diverged clients sort last."""
    d = distances(client_losses, server_loss)
    k = max(1, int(round(frac * len(d))))
    return sorted(np.argsort(d, kind="stable")[:k].tolist())


def selection_variance(client_losses: Sequence[float], server_loss: float,
                       selected: Sequence[int]) -> dict:
    """Empirical check of Cor. VI.8.2: Var over selected ≤ Var over all.

    Variances are taken over the *finite* distances only, so one
    diverged client does not turn every ``RoundRecord``'s ``var_all``
    into NaN; 0.0 when no finite entries remain.
    """
    d = distances(client_losses, server_loss)
    d2 = d ** 2

    def _var(v: np.ndarray) -> float:
        v = v[np.isfinite(v)]
        return float(v.mean()) if v.size else 0.0

    return {"var_all": _var(d2),
            "var_selected": _var(d2[list(selected)])}
