"""Alignment-based client selection (Sec. III-B).

d_i^t = |L_i^t − L_s^t|; keep the devices with the smallest k% distances.
Reduces aggregation variance by (1 − k/N) (Cor. VI.8.2).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def distances(client_losses: Sequence[float], server_loss: float
              ) -> np.ndarray:
    return np.abs(np.asarray(client_losses, np.float64) - server_loss)


def select_aligned(client_losses: Sequence[float], server_loss: float,
                   frac: float) -> List[int]:
    """Indices of the top-k% most aligned clients (ties → lower index).
    Always returns at least one client."""
    d = distances(client_losses, server_loss)
    k = max(1, int(round(frac * len(d))))
    return sorted(np.argsort(d, kind="stable")[:k].tolist())


def selection_variance(client_losses: Sequence[float], server_loss: float,
                       selected: Sequence[int]) -> dict:
    """Empirical check of Cor. VI.8.2: Var over selected ≤ Var over all."""
    d = distances(client_losses, server_loss)
    d2 = d ** 2
    return {"var_all": float(d2.mean()),
            "var_selected": float(d2[list(selected)].mean())}
