"""Algorithm 1 — the LLM-QFL federated orchestrator.

Plain ``QFL`` (the paper's FedAvg baseline) and ``LLM-QFL`` (regulated
optimizer + alignment selection + early termination + distillation) share
this loop; a ``RunConfig`` selects the variant:

  - method="qfl"                      : fixed maxiter, aggregate all.
  - method="llm-qfl", select_frac=1.0 : LLM-QFL-all.
  - method="llm-qfl", select_frac=0.1 : LLM-QFL-selected.

Per round (T total):  broadcast θ_g → [regulate maxiter → local grad-free
training on F_i + λ·KL + µ·prox] per device → alignment selection →
weighted aggregation → server eval → termination check.  Communication
time is accounted through the quantum backend's latency model (Table I).

On finite-shot backends every evaluation — optimizer objectives, the
per-round client-loss reports, and the server loss/accuracy — draws its
shots under the ``backends.py`` key-derivation contract
``eval_key(PRNGKey(seed), round, client, slot)``: optimizer evaluations
use client ids ``0..C-1`` with the slot schedule owned by ``gradfree``
(sequential) / the batched optimizers, reports use ``REPORT_EVAL_SLOT``
on the client's stream, and server-side evaluations use the reserved
``SERVER_CLIENT`` id.  Both engines share the derivation, so noisy runs
are deterministic-by-seed and engine-parity holds draw-for-draw.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace as dc_replace
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distill, regulation, selection
from repro.core.llm_client import run_sequential_stage, task_llm_config
from repro.core.termination import TerminationCriterion
from repro.data.tasks import FederatedTask
from repro.optim.gradfree import GradFreeOptimizer
from repro.quantum import backends as backend_mod
from repro.quantum import qnn


@dataclass
class RunConfig:
    method: str = "llm-qfl"            # "qfl" | "llm-qfl"
    select_frac: float = 1.0           # 1.0 = all; 0.1 = top-10% aligned
    regulation: str = "adaptive"       # App. F variant
    maxiter0: int = 10
    maxiter_cap: int = 100
    n_rounds: int = 10
    epsilon: float = 1e-3
    lam: float = 0.1                   # λ distillation weight (Eq. 6)
    mu: float = 0.01                   # µ prox weight (Eq. 6)
    optimizer: str = "nelder-mead"     # | "spsa"
    engine: str = "sequential"         # | "batched" (one jitted round prog)
    rounds: str = "host"               # | "fused" (R rounds as ONE jitted
                                       # scan — core/fused_rounds.py;
                                       # requires engine="batched")
    c_round: Optional[int] = None      # fused-only: per-round cohort size
                                       # drawn from the client population
                                       # (None = full participation)
    dropout: float = 0.0               # fused-only: per-round client
                                       # dropout probability
    n_devices: Optional[int] = None    # 'clients' mesh width for the
                                       # batched engine (None/1 = single
                                       # device, the parity reference)
    backend: str = "exact"
    shots_override: Optional[int] = None   # replace the backend's shots
                                           # (0 = channel-only ablation)
    n_qubits: int = 4                  # must match the task's feature dim
    llm_name: str = "tiny-llm"
    llm_steps: int = 30
    llm_lr: float = 3e-3
    distill_rho: float = 0.25
    qnn_kind: str = ""                 # "" → vqc for 2-class, qcnn for 3
    early_stop: bool = True
    seed: int = 0

    @property
    def uses_llm(self) -> bool:
        return self.method == "llm-qfl"


@dataclass
class RoundRecord:
    t: int
    maxiters: List[int]
    ratios: List[float]
    client_losses: List[float]
    selected: List[int]
    server_loss: float
    server_val_acc: float
    server_test_acc: float
    comm_time_s: float
    cum_evals: List[int]
    var_all: float = 0.0
    var_selected: float = 0.0


@dataclass
class RunResult:
    config: RunConfig
    rounds: List[RoundRecord] = field(default_factory=list)
    llm_losses: List[float] = field(default_factory=list)
    llm_f1: List[float] = field(default_factory=list)
    llm_finetune_time_s: float = 0.0
    theta_g: Optional[np.ndarray] = None
    terminated_early: bool = False

    def series(self, attr: str):
        return [getattr(r, attr) for r in self.rounds]


class Orchestrator:
    def __init__(self, task: FederatedTask, rc: RunConfig):
        self.task = task
        self.rc = rc
        if rc.engine not in ("sequential", "batched"):
            raise ValueError(f"unknown engine {rc.engine!r}")
        if rc.rounds not in ("host", "fused"):
            raise ValueError(f"unknown rounds mode {rc.rounds!r}; "
                             "'host' or 'fused'")
        if rc.rounds == "fused" and rc.engine != "batched":
            raise ValueError(
                "rounds='fused' runs the whole loop as one device "
                "program and needs the batched local phase; use "
                "engine='batched'")
        if rc.rounds != "fused" and (rc.c_round is not None
                                     or rc.dropout != 0.0):
            raise ValueError(
                "c_round / dropout are population semantics of the "
                "fused round loop; set rounds='fused'")
        if rc.n_devices is not None and rc.n_devices > 1 \
                and rc.engine != "batched":
            raise ValueError(
                "n_devices > 1 shards the batched engine's client axis; "
                "the sequential engine is single-device — use "
                "engine='batched'")
        kind = rc.qnn_kind or ("vqc" if task.n_classes == 2 else "qcnn")
        feat_dim = int(task.clients[0].qX.shape[1])
        if feat_dim != rc.n_qubits:
            raise ValueError(
                f"n_qubits={rc.n_qubits} but the task encodes "
                f"{feat_dim}-dim features (build_task(n_features=...))")
        self.spec = qnn.QNNSpec(kind, n_qubits=rc.n_qubits,
                                n_classes=task.n_classes)
        self.backend = backend_mod.get(rc.backend)
        if rc.shots_override is not None:
            if rc.shots_override < 0:
                raise ValueError("shots_override must be >= 0")
            self.backend = dc_replace(self.backend,
                                      shots=int(rc.shots_override))
        # root of the shot-noise key chain (fold_in round/client/slot);
        # distinct from the split-based init-param stream below
        self._noise_base = jax.random.PRNGKey(rc.seed)
        if rc.engine == "batched":
            # tape-compiled forward: same math (≤1e-6), compiles in a
            # fraction of the unrolled eager circuit's time
            from repro.quantum import tape as tape_mod
            self.fwd = tape_mod.make_tape_forward(self.spec)
        else:
            self.fwd = qnn.make_forward(self.spec)
        self._key = jax.random.PRNGKey(rc.seed)
        self._engine = None

    # -- helpers -------------------------------------------------------------
    def _measure_probs(self, theta: np.ndarray, X, key) -> jnp.ndarray:
        """Forward + full backend measurement (channel, keyed sampling)."""
        probs = self.fwd(jnp.asarray(theta, jnp.float32), jnp.asarray(X))
        return self.backend.transform_probs(probs, key)

    def _nll(self, theta: np.ndarray, X, y, key=None) -> float:
        probs = self._measure_probs(theta, X, key)
        return float(qnn.nll_loss(probs, jnp.asarray(y)))

    def _acc(self, theta: np.ndarray, X, y, key=None) -> float:
        # accuracy is measured through the backend like the loss — the
        # Table-I noisy-vs-exact accuracy ordering is observed, not
        # assumed from the noiseless forward
        probs = self._measure_probs(theta, X, key)
        return float(qnn.accuracy(probs, jnp.asarray(y)))

    def _mkey(self, t: int, client: int, slot: int):
        """Measurement key for a reporting/server eval; None when the
        backend does not sample (channel-only is key-free)."""
        if not self.backend.shots:
            return None
        return backend_mod.eval_key(self._noise_base, t, client, slot)

    def _eval_stream(self, t: int, client: int):
        """slot → key stream for client ``client``'s optimizer in round
        ``t`` (the contract's sequential-path form); None when exact."""
        if not self.backend.shots:
            return None
        base = jax.random.fold_in(
            jax.random.fold_in(self._noise_base, t), client)
        return lambda slot: jax.random.fold_in(base, slot)

    def _client_loss_fn(self, i: int):
        c = self.task.clients[i]
        X, y = jnp.asarray(c.qX), jnp.asarray(c.qy)
        keyed = self.backend.shots > 0
        base = qnn.make_loss_fn(self.spec, X, y, backend=self.backend)
        if not self.rc.uses_llm:
            if keyed:
                return lambda th, key: float(
                    base(jnp.asarray(th, jnp.float32), key))
            return lambda th: float(base(jnp.asarray(th, jnp.float32)))
        teacher = self._teacher_probs[i]
        return distill.make_client_objective(
            base, self.fwd, X, teacher, self._theta_g,
            lam=self.rc.lam, mu=self.rc.mu, keyed=keyed)

    # -- Step 1: LLM fine-tuning (round 1 only) -------------------------------
    def _llm_round(self):
        """Fine-tune every client's LoRA adapters, distill toward the
        FedAvg teacher, and collect the regulation losses / soft labels.

        Engine dispatch mirrors the quantum round: ``engine="batched"``
        runs the whole stage as one jitted device program
        (``core/batched_llm.BatchedLLMEngine`` — stacked adapters,
        vmapped train steps, on-device distill/evals, optionally sharded
        over the 'clients' mesh); ``engine="sequential"`` is the
        per-client parity reference.  Both draw minibatches under the
        ``llm_client.llm_key(llm_root(seed), client, step)`` contract,
        so the two paths are draw-for-draw identical.
        """
        rc, task = self.rc, self.task
        t0 = time.time()
        cfg = task_llm_config(rc.llm_name, task.vocab_size, task.llm_seq_len)
        from repro.models import model as M
        self._key, k0 = jax.random.split(self._key)
        base = M.init_params(cfg, k0, dtype=jnp.float32)
        if rc.engine == "batched":
            from repro.core.batched_llm import BatchedLLMEngine
            self.llm_clients = None     # per-client wrappers exist only
                                        # on the sequential path
            self._llm_engine = BatchedLLMEngine(
                task, cfg, base, seed=rc.seed, lr=rc.llm_lr,
                steps=rc.llm_steps, rho=rc.distill_rho,
                n_devices=rc.n_devices)
            out = self._llm_engine.run()
            self._llm_losses = [float(x) for x in out.losses]
            self._llm_f1 = [float(x) for x in out.f1]
            self._teacher_probs = self._llm_engine.teacher_probs_list(
                task, out.teacher)
        else:
            (self.llm_clients, self._llm_losses, self._llm_f1,
             self._teacher_probs) = run_sequential_stage(
                task, cfg, base, seed=rc.seed, lr=rc.llm_lr,
                steps=rc.llm_steps, rho=rc.distill_rho)
        return time.time() - t0

    # -- main loop -------------------------------------------------------------
    def run(self) -> RunResult:
        rc, task = self.rc, self.task
        res = RunResult(config=rc)

        self._key, k = jax.random.split(self._key)
        self._theta_g = np.asarray(self.spec.init_params(k), np.float64)

        if rc.uses_llm:
            res.llm_finetune_time_s = self._llm_round()
            res.llm_losses = list(self._llm_losses)
            res.llm_f1 = list(self._llm_f1)
        else:
            self._teacher_probs = [None] * task.n_clients

        if rc.rounds == "fused":
            # the whole round loop — local phase, FedAvg, regulation,
            # selection, termination — as ONE jitted scan over rounds
            return self._run_fused(res)

        if rc.engine == "batched":
            # Local phase as one device program: tape-compiled circuits,
            # vmapped clients, masked per-client budgets driving the
            # native batched optimizer (SPSA or Nelder–Mead).
            from repro.core.batched_engine import BatchedRoundEngine
            self._engine = BatchedRoundEngine(
                task, self.spec, self.backend, lam=rc.lam, mu=rc.mu,
                use_llm=rc.uses_llm, teacher_probs=self._teacher_probs,
                seeds=[rc.seed * 997 + i for i in range(task.n_clients)],
                max_iter=max(rc.maxiter_cap, rc.maxiter0),
                optimizer=rc.optimizer, seed=rc.seed,
                n_devices=rc.n_devices)

        maxiters = [rc.maxiter0] * task.n_clients
        last_losses = [float("inf")] * task.n_clients
        cum_evals = [0] * task.n_clients
        term = TerminationCriterion(epsilon=rc.epsilon,
                                    t_max=rc.n_rounds)

        for t in range(1, rc.n_rounds + 1):
            ratios = [1.0] * task.n_clients
            # Step 2: regulation (Alg. 1 lines 11–17; only after round 1)
            if rc.uses_llm and t > 1:
                for i in range(task.n_clients):
                    llm_l = self._llm_losses[i]
                    if np.isfinite(last_losses[i]) and llm_l > 0:
                        ratios[i] = last_losses[i] / llm_l
                    maxiters[i] = regulation.regulate(
                        maxiters[i], last_losses[i], llm_l,
                        variant=rc.regulation, cap=rc.maxiter_cap)

            # local training: one fused device program (batched) or the
            # per-client sequential reference
            thetas, losses, comm_t = [], [], 0.0
            if self._engine is not None:
                th_stack, n_evals = self._engine.run_round(self._theta_g,
                                                           maxiters, t)
                for i in range(task.n_clients):
                    thetas.append(th_stack[i])
                    # report pure F_i (no penalty) as the device loss
                    losses.append(self._nll(
                        th_stack[i], task.clients[i].qX,
                        task.clients[i].qy,
                        key=self._mkey(t, i,
                                       backend_mod.REPORT_EVAL_SLOT)))
                    cum_evals[i] += int(n_evals[i])
                    # metered-run evals only, matching the sequential
                    # path's (opt.n_evals - n0) — init is not comm-billed
                    comm_t = max(comm_t, self.backend.eval_time(
                        task.clients[i].n)
                        * (int(n_evals[i]) - self._engine.init_evals))
            else:
                for i in range(task.n_clients):
                    fn = self._client_loss_fn(i)
                    opt = GradFreeOptimizer(fn, self._theta_g,
                                            method=rc.optimizer,
                                            seed=rc.seed * 997 + i,
                                            key_stream=self._eval_stream(
                                                t, i))
                    n0 = opt.n_evals
                    th, f = opt.run(maxiters[i])
                    thetas.append(np.asarray(th, np.float64))
                    # report pure F_i (no penalty) as the device loss
                    losses.append(self._nll(
                        th, task.clients[i].qX, task.clients[i].qy,
                        key=self._mkey(t, i,
                                       backend_mod.REPORT_EVAL_SLOT)))
                    cum_evals[i] += opt.n_evals
                    comm_t = max(comm_t, self.backend.eval_time(
                        task.clients[i].n) * (opt.n_evals - n0))
            last_losses = list(losses)

            # server loss of the current global model (pre-aggregation)
            server_loss_pre = self._nll(
                self._theta_g, task.val_qX, task.val_qy,
                key=self._mkey(t, backend_mod.SERVER_CLIENT,
                               backend_mod.SERVER_SLOT_LOSS_PRE))

            # client selection (Sec. III-B)
            if rc.uses_llm and rc.select_frac < 1.0:
                sel = selection.select_aligned(losses, server_loss_pre,
                                               rc.select_frac)
            else:
                sel = list(range(task.n_clients))
            var = selection.selection_variance(losses, server_loss_pre, sel)

            # aggregation (Eq. 3) over the selected set
            w = np.asarray([task.weights[i] for i in sel])
            w = w / w.sum()
            self._theta_g = sum(
                wi * thetas[i] for wi, i in zip(w, sel))

            server_loss = self._nll(
                self._theta_g, task.val_qX, task.val_qy,
                key=self._mkey(t, backend_mod.SERVER_CLIENT,
                               backend_mod.SERVER_SLOT_LOSS_POST))
            rec = RoundRecord(
                t=t, maxiters=list(maxiters), ratios=ratios,
                client_losses=losses, selected=sel,
                server_loss=server_loss,
                server_val_acc=self._acc(
                    self._theta_g, task.val_qX, task.val_qy,
                    key=self._mkey(t, backend_mod.SERVER_CLIENT,
                                   backend_mod.SERVER_SLOT_VAL_ACC)),
                server_test_acc=self._acc(
                    self._theta_g, task.test_qX, task.test_qy,
                    key=self._mkey(t, backend_mod.SERVER_CLIENT,
                                   backend_mod.SERVER_SLOT_TEST_ACC)),
                comm_time_s=comm_t, cum_evals=list(cum_evals),
                var_all=var["var_all"], var_selected=var["var_selected"])
            res.rounds.append(rec)

            if term.update(server_loss, t) and rc.early_stop:
                res.terminated_early = t < rc.n_rounds
                break

        res.theta_g = self._theta_g
        return res

    def _run_fused(self, res: RunResult) -> RunResult:
        """Dispatch to ``core/fused_rounds.FusedRoundDriver`` and unpack
        its scanned outputs into the same ``RoundRecord`` stream the
        host loop produces.  Per-client fields are population-sized
        (C = task.n_clients): rounds a client did not participate in
        report NaN losses / 1.0 ratios for it, and its budget / eval
        rows simply carry forward — the inertness the fused driver
        guarantees."""
        rc, task = self.rc, self.task
        from repro.core.fused_rounds import FusedRoundDriver
        driver = FusedRoundDriver(
            task, self.spec, self.backend, optimizer=rc.optimizer,
            seed=rc.seed, lam=rc.lam, mu=rc.mu, use_llm=rc.uses_llm,
            teacher_probs=self._teacher_probs if rc.uses_llm else None,
            llm_losses=self._llm_losses if rc.uses_llm else None,
            maxiter0=rc.maxiter0, maxiter_cap=rc.maxiter_cap,
            regulation=rc.regulation, select_frac=rc.select_frac,
            epsilon=rc.epsilon, n_rounds=rc.n_rounds,
            early_stop=rc.early_stop, c_round=rc.c_round,
            dropout=rc.dropout, n_devices=rc.n_devices)
        out = driver.run(self._theta_g)
        C = task.n_clients
        for r in range(rc.n_rounds):
            if not out.active[r]:
                break
            t = r + 1
            cohort = out.cohort[r]
            pos = np.nonzero(cohort < C)[0]       # mesh padding rows out
            losses = np.full(C, np.nan)
            losses[cohort[pos]] = out.losses[r][pos]
            ratios = np.ones(C)
            ratios[cohort[pos]] = out.ratios[r][pos]
            sel = sorted(int(cohort[p])
                         for p in np.nonzero(out.selected[r])[0])
            var = selection.selection_variance(
                losses.tolist(), float(out.server_loss_pre[r]), sel)
            res.rounds.append(RoundRecord(
                t=t, maxiters=out.budgets[r][:C].tolist(),
                ratios=ratios.tolist(), client_losses=losses.tolist(),
                selected=sel, server_loss=float(out.server_loss[r]),
                server_val_acc=float(out.val_acc[r]),
                server_test_acc=float(out.test_acc[r]),
                comm_time_s=float(out.comm_time_s[r]),
                cum_evals=out.cum_evals[r][:C].tolist(),
                var_all=var["var_all"], var_selected=var["var_selected"]))
            if out.stop[r] and rc.early_stop:
                res.terminated_early = t < rc.n_rounds
                break
        self._theta_g = np.asarray(out.theta_g, np.float64)
        res.theta_g = self._theta_g
        return res


def run_experiment(task: FederatedTask, **overrides) -> RunResult:
    return Orchestrator(task, RunConfig(**overrides)).run()
