"""Knowledge distillation term K(θ_g, θ_i) (Eq. 5–6, DESIGN.md §6.1).

The fine-tuned local LLM produces per-example soft class distributions on
the client's shard (teacher).  The client objective adds
λ·KL(teacher ‖ student) + µ·‖θ − θ_g‖², so the gradient-free optimizer
minimizes  F_i(θ) + λ·K + µ·prox  — local adaptation + global coherence +
smooth convergence, exactly the three forces of Eq. (6).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


def kl_divergence(p_teacher: jnp.ndarray, p_student: jnp.ndarray,
                  eps: float = 1e-9) -> jnp.ndarray:
    """Mean KL(p_t ‖ p_s) over the batch; both (B, C) prob simplexes."""
    pt = jnp.clip(p_teacher, eps, 1.0)
    ps = jnp.clip(p_student, eps, 1.0)
    return jnp.mean(jnp.sum(pt * (jnp.log(pt) - jnp.log(ps)), axis=-1))


def make_client_objective(qnn_loss_fn: Callable, qnn_forward: Callable,
                          qX: jnp.ndarray,
                          teacher_probs: Optional[jnp.ndarray],
                          theta_g: Optional[np.ndarray], *,
                          lam: float = 0.1, mu: float = 0.01,
                          keyed: bool = False) -> Callable:
    """theta (np) → float:  F_i + λ·KL(teacher‖student) + µ·‖θ−θ_g‖²/d.

    ``keyed=True`` when ``qnn_loss_fn`` is a finite-shot loss (called as
    ``fn(theta, key)``); the key feeds only the F_i shot sampling — the
    KL penalty reads the *raw* student probabilities, mirroring the
    batched engine's objective term for term.
    """
    tg = None if theta_g is None else jnp.asarray(theta_g, jnp.float32)

    @jax.jit
    def _penalties(theta):
        out = jnp.zeros((), jnp.float32)
        if teacher_probs is not None and lam > 0:
            probs = qnn_forward(theta, qX)
            out = out + lam * kl_divergence(teacher_probs, probs)
        if tg is not None and mu > 0:
            out = out + mu * jnp.mean((theta - tg) ** 2)
        return out

    if keyed:
        def objective_keyed(theta_np, key) -> float:
            theta = jnp.asarray(theta_np, jnp.float32)
            return float(qnn_loss_fn(theta, key)) + float(_penalties(theta))

        return objective_keyed

    def objective(theta_np) -> float:
        theta = jnp.asarray(theta_np, jnp.float32)
        return float(qnn_loss_fn(theta)) + float(_penalties(theta))

    return objective
