"""Optimizer regulation — the paper's "LLM as smart controller" law.

Base law (Sec. III-B):   Regulated_Iter = iter · (L_i^t / L_LLM^t)
applied only when the quantum model underperforms the LLM benchmark
(Alg. 1 line 12: ``if LLM_l < QNN_l``).

App. F variants (Fig. 20): incremental / adaptive / logarithmic /
dynamic-weighted.  All return an integer in [min_iter, cap].
"""
from __future__ import annotations

import math

VARIANTS = ("adaptive", "incremental", "logarithmic", "dynamic")


def regulate(maxiter: int, qnn_loss: float, llm_loss: float, *,
             variant: str = "adaptive", cap: int = 100, min_iter: int = 1,
             weight: float = 0.5, increment: int = 2) -> int:
    """New maxiter given the device's latest loss vs the LLM reference."""
    if llm_loss <= 0 or not math.isfinite(llm_loss):
        return maxiter
    if not math.isfinite(qnn_loss):        # diverged client (NaN/inf loss):
        return max(min_iter, min(maxiter, cap))   # hold the current budget
    if qnn_loss <= llm_loss:               # Alg. 1: only boost when behind
        return max(min_iter, min(maxiter, cap))
    ratio = qnn_loss / llm_loss

    if variant == "adaptive":              # ratio * maxiter (paper default)
        new = maxiter * ratio
    elif variant == "incremental":         # gradual fixed-size increments
        new = maxiter + increment * min(math.ceil(ratio), 5)
    elif variant == "logarithmic":         # damped for large ratios
        new = maxiter * (1.0 + math.log(ratio))
    elif variant == "dynamic":             # weighted blend with current
        new = (1 - weight) * maxiter + weight * maxiter * ratio
    else:
        raise ValueError(f"unknown variant {variant!r}; one of {VARIANTS}")
    return int(max(min_iter, min(round(new), cap)))
