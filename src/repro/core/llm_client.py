"""Per-client LLM fine-tuning (Alg. 1 Step 1) on the repro.models substrate.

Every client shares a frozen randomly-initialized base LLM (the "pretrained"
model; DESIGN.md §2 — no offline checkpoints) and fine-tunes **LoRA
adapters** on its private shard during round 1 only.  The fine-tuned LLM
then provides:
  - ``eval_loss``     : the reference loss L_LLM^t for optimizer regulation,
  - ``teacher_probs`` : per-example soft class labels for KL distillation,
  - ``f1``            : macro-F1 (paper Fig. 24 benchmark axis).

"Distill LLM using a global model" (Alg. 1 line 8) is realized as adapter
blending toward the weighted FedAvg adapter: a_i ← (1−ρ)·a_i + ρ·a_g.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import paper_models
from repro.models import model as M
from repro.optim import adamw


def task_llm_config(base_name: str, vocab_size: int, seq_len: int):
    """Clone a paper LLM config with the task vocabulary.

    ``tiny-llm`` is the CPU-scale default; pass 'llama3.2-1b' etc. for the
    full paper configs (dry-run scale).
    """
    base = {
        "tiny-llm": paper_models.TINY_LLM,
        "llama3.2-1b": paper_models.LLAMA32_1B,
        "gpt2": paper_models.GPT2,
        "deepseek-llm-7b-base": paper_models.DEEPSEEK_7B,
    }[base_name]
    return dataclasses.replace(base, vocab_size=vocab_size)


class LLMClient:
    """One client's local LLM: shared frozen base + private LoRA adapters."""

    def __init__(self, cfg, base_params, key, *, n_labels: int,
                 lr: float = 3e-3, batch_size: int = 16):
        self.cfg = cfg
        self.base = base_params
        self.n_labels = n_labels
        self.lr = lr
        self.batch_size = batch_size
        self.adapters = M.init_adapters(cfg, key, base_params)
        self.opt_state = adamw.init(self.adapters)
        self._step = jax.jit(M.make_train_step(
            cfg, n_microbatches=1, lr=lr,
            opts=M.FwdOptions(remat=False)))
        self._key = key

    # -- fine-tuning (round 1 / periodic refresh) ---------------------------
    def fine_tune(self, batch: Dict[str, np.ndarray], *, steps: int = 30
                  ) -> float:
        toks = jnp.asarray(batch["tokens"])
        ys = jnp.asarray(batch["labels"])
        n = toks.shape[0]
        bs = min(self.batch_size, n)
        last = float("nan")
        for s in range(steps):
            self._key, k = jax.random.split(self._key)
            idx = jax.random.choice(k, n, (bs,), replace=n < bs)
            mb = {"tokens": toks[idx], "labels": ys[idx]}
            self.adapters, self.opt_state, metrics = self._step(
                self.base, self.adapters, self.opt_state, mb)
            last = float(metrics["loss"])
        return last

    # -- evaluation ----------------------------------------------------------
    def _label_logits(self, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Logits over the label-token block at each example's label
        position.  Returns (logits (B, n_labels), gold (B,))."""
        toks = jnp.asarray(batch["tokens"])
        ys = jnp.asarray(batch["labels"])
        hidden, _, _ = M.forward(self.cfg, self.base, self.adapters,
                                 {"tokens": toks},
                                 M.FwdOptions(remat=False))
        pos = jnp.argmax((ys >= 0).astype(jnp.int32), axis=1)       # (B,)
        h = jnp.take_along_axis(hidden, pos[:, None, None], axis=1)[:, 0]
        head = (self.base["embed"].T if self.cfg.tie_embeddings
                else self.base["lm_head"])
        label_head = head[:, -self.n_labels:].astype(jnp.float32)
        logits = h.astype(jnp.float32) @ label_head
        gold_tok = jnp.take_along_axis(ys, pos[:, None], axis=1)[:, 0]
        gold = gold_tok - (self.cfg.vocab_size - self.n_labels)
        return logits, gold

    def eval_loss(self, batch) -> float:
        """Classification NLL on the label positions — L_LLM^t."""
        logits, gold = self._label_logits(batch)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, gold[:, None], axis=1).mean()
        return float(nll)

    def teacher_probs(self, batch) -> jnp.ndarray:
        """Soft class labels (B, n_labels) for distillation."""
        logits, _ = self._label_logits(batch)
        return jax.nn.softmax(logits, axis=-1)

    def f1(self, batch) -> float:
        logits, gold = self._label_logits(batch)
        pred = np.asarray(jnp.argmax(logits, axis=-1))
        gold = np.asarray(gold)
        f1s = []
        for c in range(self.n_labels):
            tp = float(((pred == c) & (gold == c)).sum())
            fp = float(((pred == c) & (gold != c)).sum())
            fn = float(((pred != c) & (gold == c)).sum())
            p = tp / (tp + fp) if tp + fp else 0.0
            r = tp / (tp + fn) if tp + fn else 0.0
            f1s.append(2 * p * r / (p + r) if p + r else 0.0)
        return float(np.mean(f1s))


def fedavg_adapters(adapter_list, weights) -> Dict:
    """Weighted average of client adapter pytrees (global LLM teacher)."""
    w = np.asarray(weights, np.float64)
    w = w / w.sum()
    out = jax.tree.map(lambda *xs: sum(wi * x for wi, x in zip(w, xs)),
                       *adapter_list)
    return out


def distill_to_global(clients, weights, *, rho: float = 0.25):
    """a_i ← (1−ρ)·a_i + ρ·a_g  (Alg. 1 line 8)."""
    a_g = fedavg_adapters([c.adapters for c in clients], weights)
    for c in clients:
        c.adapters = jax.tree.map(
            lambda a, g: (1 - rho) * a + rho * g, c.adapters, a_g)
    return a_g
