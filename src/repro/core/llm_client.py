"""Per-client LLM fine-tuning (Alg. 1 Step 1) on the repro.models substrate.

Every client shares a frozen randomly-initialized base LLM (the "pretrained"
model; DESIGN.md §2 — no offline checkpoints) and fine-tunes **LoRA
adapters** on its private shard during round 1 only.  The fine-tuned LLM
then provides:
  - ``eval_loss``     : the reference loss L_LLM^t for optimizer regulation,
  - ``teacher_probs`` : per-example soft class labels for KL distillation,
  - ``f1``            : macro-F1 (paper Fig. 24 benchmark axis).

"Distill LLM using a global model" (Alg. 1 line 8) is realized as adapter
blending toward the weighted FedAvg adapter: a_i ← (1−ρ)·a_i + ρ·a_g.

This module owns the **sequential parity reference** for the fine-tuning
stage: the pure per-client functions (``label_logits``/``masked_label_nll``/
``masked_macro_f1``) plus the thin ``LLMClient`` wrapper that runs them one
client at a time.  ``core/batched_llm.py`` runs the same math stacked over
all clients in one jitted program; both paths draw identically under the
key contract below, so batched == sequential draw-for-draw.

LLM key-derivation contract
---------------------------
Mirroring the quantum stage's ``eval_key(seed, round, client, slot)``
contract, every random draw of the fine-tuning stage derives from

    ``llm_key(llm_root(seed), client, step)``
    = ``fold_in(fold_in(fold_in(PRNGKey(seed), LLM_DOMAIN), client), step)``

where ``client`` is the client's *position* ``0..C-1`` (padding rows on a
mesh take ids ``C..``, appended after every real client — sharding never
renumbers) and ``step`` is the **global fine-tune step index**:

  - minibatch draw of step ``s``   → ``llm_key(root, client, s)``
    (``sample_minibatch_idx``: with-replacement uniform indices — a pure
    function of the key and the shard size, so the batched engine's
    vmapped draw is bitwise the sequential draw),
  - adapter initialization         → ``llm_key(root, client,
    LLM_INIT_STEP)`` (a reserved step id at the top of the range).

``LLM_DOMAIN`` separates this chain from the orchestrator's shot-noise
chain (which folds round indices into the same seed root).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import paper_models
from repro.models import model as M
from repro.optim import adamw
from repro.peft import lora as lora_mod

# Reserved ids of the LLM key contract (module docstring).  LLM_DOMAIN is
# folded once into PRNGKey(seed) so the fine-tune chain and the quantum
# shot-noise chain (fold_in(round)) can never collide; LLM_INIT_STEP is
# the adapter-init draw's reserved step id.
LLM_DOMAIN = 0x4C4C4D            # "LLM"
LLM_INIT_STEP = 0x7FFFFFFF


def llm_root(seed: int) -> jax.Array:
    """Root of the fine-tuning stage's key chain for a run seed."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), LLM_DOMAIN)


def llm_key(root: jax.Array, client, step) -> jax.Array:
    """The contract's key chain; ``client``/``step`` may be traced ints
    (usable under ``jit`` / ``vmap`` / ``lax.scan``)."""
    return jax.random.fold_in(jax.random.fold_in(root, client), step)


def sample_minibatch_idx(key: jax.Array, n, batch_size: int) -> jnp.ndarray:
    """With-replacement uniform minibatch indices in ``[0, n)``.

    ``n`` may be a traced per-client shard size (clamped to >= 1 so inert
    padding clients index row 0 of their padded stack); ``batch_size`` is
    static, so every client draws the same shape and the batched engine
    can vmap this over ``(keys, ns)`` — per-lane draws are bitwise the
    sequential per-client calls.
    """
    u = jax.random.uniform(key, (batch_size,))
    n = jnp.maximum(n, 1)
    return jnp.minimum((u * n).astype(jnp.int32), n - 1)


def task_llm_config(base_name: str, vocab_size: int, seq_len: int):
    """Clone a paper LLM config with the task vocabulary.

    ``tiny-llm`` is the CPU-scale default; pass 'llama3.2-1b' etc. for the
    full paper configs (dry-run scale).
    """
    base = {
        "tiny-llm": paper_models.TINY_LLM,
        "llama3.2-1b": paper_models.LLAMA32_1B,
        "gpt2": paper_models.GPT2,
        "deepseek-llm-7b-base": paper_models.DEEPSEEK_7B,
    }[base_name]
    return dataclasses.replace(base, vocab_size=vocab_size)


# ---------------------------------------------------------------------------
# pure per-client evaluation math (shared by both engines)
# ---------------------------------------------------------------------------
def label_logits(cfg, params: Dict, adapters: Dict, tokens: jnp.ndarray,
                 labels: jnp.ndarray, n_labels: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Logits over the label-token block at each example's label position.

    ``tokens``/``labels`` are one client's ``(B, L)`` shard (possibly
    zero/-1 padded rows — a padded row has no ``label >= 0`` position, so
    ``pos`` degenerates to 0 and its gold index is clipped; callers mask
    those rows out).  Returns (logits (B, n_labels) f32, gold (B,)).
    """
    hidden, _, _ = M.forward(cfg, params, adapters, {"tokens": tokens},
                             M.FwdOptions(remat=False))
    pos = jnp.argmax((labels >= 0).astype(jnp.int32), axis=1)        # (B,)
    h = jnp.take_along_axis(hidden, pos[:, None, None], axis=1)[:, 0]
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    label_head = head[:, -n_labels:].astype(jnp.float32)
    logits = h.astype(jnp.float32) @ label_head
    gold_tok = jnp.take_along_axis(labels, pos[:, None], axis=1)[:, 0]
    gold = jnp.clip(gold_tok - (cfg.vocab_size - n_labels), 0,
                    n_labels - 1)
    return logits, gold


def masked_label_nll(logits: jnp.ndarray, gold: jnp.ndarray,
                     mask: jnp.ndarray) -> jnp.ndarray:
    """Classification NLL on the label positions — L_LLM^t.  Mask-weighted
    mean (denominator clamped so an all-padding client stays finite)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, gold[:, None], axis=1)[:, 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def masked_macro_f1(logits: jnp.ndarray, gold: jnp.ndarray,
                    mask: jnp.ndarray, n_labels: int) -> jnp.ndarray:
    """Macro-F1 over masked rows, fully on device (vmap-composable).

    Count accumulation is exact in f32 (integer-valued sums), so this
    matches the old host numpy implementation on unmasked inputs.
    """
    pred = jnp.argmax(logits, axis=-1)
    cls = jnp.arange(n_labels)
    is_p = (pred[:, None] == cls[None, :]).astype(jnp.float32) \
        * mask[:, None]
    is_g = (gold[:, None] == cls[None, :]).astype(jnp.float32) \
        * mask[:, None]
    tp = jnp.sum(is_p * is_g, axis=0)
    fp = jnp.sum(is_p, axis=0) - tp
    fn = jnp.sum(is_g, axis=0) - tp
    p = jnp.where(tp + fp > 0, tp / jnp.maximum(tp + fp, 1.0), 0.0)
    r = jnp.where(tp + fn > 0, tp / jnp.maximum(tp + fn, 1.0), 0.0)
    f1 = jnp.where(p + r > 0, 2 * p * r / jnp.maximum(p + r, 1e-30), 0.0)
    return jnp.mean(f1)


class LLMClient:
    """One client's local LLM: shared frozen base + private LoRA adapters.

    The thin sequential wrapper around the pure functions above — the
    parity reference for ``core/batched_llm.BatchedLLMEngine``.  All C
    instances share **one** jitted train step per config
    (``M.get_train_step``; each instance used to jit its own closure, so
    C clients paid C identical compiles), and every draw follows the
    module's ``llm_key(root, client, step)`` contract.
    """

    def __init__(self, cfg, base_params, key, *, n_labels: int,
                 lr: float = 3e-3, batch_size: int = 16,
                 client_id: int = 0):
        self.cfg = cfg
        self.base = base_params
        self.n_labels = n_labels
        self.lr = lr
        self.batch_size = batch_size
        self.client_id = client_id
        self._root = key                  # llm_root(seed) in federated runs
        self.adapters = M.init_adapters(
            cfg, llm_key(key, client_id, LLM_INIT_STEP), base_params)
        self.opt_state = adamw.init(self.adapters)
        self._step = M.get_train_step(cfg, n_microbatches=1, lr=lr,
                                      opts=M.FwdOptions(remat=False))
        self._n_steps = 0                 # global step counter (contract)

    # -- fine-tuning (round 1 / periodic refresh) ---------------------------
    def fine_tune(self, batch: Dict[str, np.ndarray], *, steps: int = 30
                  ) -> float:
        toks = jnp.asarray(batch["tokens"])
        ys = jnp.asarray(batch["labels"])
        n = toks.shape[0]
        last = float("nan")
        for _ in range(steps):
            k = llm_key(self._root, self.client_id, self._n_steps)
            self._n_steps += 1
            idx = sample_minibatch_idx(k, n, self.batch_size)
            mb = {"tokens": toks[idx], "labels": ys[idx]}
            self.adapters, self.opt_state, metrics = self._step(
                self.base, self.adapters, self.opt_state, mb)
            last = float(metrics["loss"])
        return last

    # -- evaluation ----------------------------------------------------------
    def _label_logits(self, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        toks = jnp.asarray(batch["tokens"])
        ys = jnp.asarray(batch["labels"])
        return label_logits(self.cfg, self.base, self.adapters, toks, ys,
                            self.n_labels)

    def eval_loss(self, batch) -> float:
        """Classification NLL on the label positions — L_LLM^t."""
        logits, gold = self._label_logits(batch)
        mask = jnp.ones((logits.shape[0],), jnp.float32)
        return float(masked_label_nll(logits, gold, mask))

    def teacher_probs(self, batch) -> jnp.ndarray:
        """Soft class labels (B, n_labels) for distillation."""
        logits, _ = self._label_logits(batch)
        return jax.nn.softmax(logits, axis=-1)

    def f1(self, batch) -> float:
        logits, gold = self._label_logits(batch)
        mask = jnp.ones((logits.shape[0],), jnp.float32)
        return float(masked_macro_f1(logits, gold, mask, self.n_labels))


def fedavg_adapters(adapter_list, weights) -> Dict:
    """Weighted average of client adapter pytrees (global LLM teacher)."""
    w = np.asarray(weights, np.float64)
    w = w / w.sum()
    out = jax.tree.map(lambda *xs: sum(wi * x for wi, x in zip(w, xs)),
                       *adapter_list)
    return out


def distill_to_global(clients, weights, *, rho: float = 0.25):
    """a_i ← (1−ρ)·a_i + ρ·a_g  (Alg. 1 line 8)."""
    a_g = fedavg_adapters([c.adapters for c in clients], weights)
    for c in clients:
        c.adapters = lora_mod.blend_adapters(c.adapters, a_g, rho)
    return a_g


def run_sequential_stage(task, cfg, base_params, *, seed: int,
                         lr: float = 3e-3, steps: int = 30,
                         batch_size: int = 16, rho: float = 0.25):
    """The whole fine-tuning stage, one client at a time — the parity
    reference for ``core/batched_llm.BatchedLLMEngine`` (the orchestrator's
    ``engine="sequential"`` branch and ``bench_llm_round`` both run this).

    Returns ``(clients, losses, f1s, teachers)`` with evaluations taken
    *after* the distillation blend, matching Alg. 1's ordering.
    """
    root = llm_root(seed)
    clients = []
    for i in range(task.n_clients):
        cl = LLMClient(cfg, base_params, root, client_id=i,
                       n_labels=task.n_classes, lr=lr,
                       batch_size=batch_size)
        cl.fine_tune(task.clients[i].llm_batch, steps=steps)
        clients.append(cl)
    distill_to_global(clients, task.weights, rho=rho)
    losses = [cl.eval_loss(task.clients[i].llm_batch)
              for i, cl in enumerate(clients)]
    f1s = [cl.f1(task.clients[i].llm_batch)
           for i, cl in enumerate(clients)]
    teachers = [cl.teacher_probs(task.clients[i].llm_batch)
                for i, cl in enumerate(clients)]
    return clients, losses, f1s, teachers
