"""Batched federated round engine — one jitted program per local phase.

The sequential orchestrator trains clients one at a time, and every
optimizer evaluation is a host↔device roundtrip (``float(fn(x))``).  This
engine executes the **entire local-training phase of a round** — all
clients, every regulated SPSA iteration, the distillation objective — as
a single compiled device program built from:

  - the circuit tape compiler (``repro.quantum.tape``): the client QNN as
    a ``lax.scan`` over fused batched gate kernels on flat statevectors,
  - a device-resident masked optimizer — batched SPSA
    (``repro.optim.batched_spsa``) or batched Nelder–Mead
    (``repro.optim.batched_nm``, the paper's default method run natively:
    speculative (C, n+3, P) candidate batches + masked branch selection),
  - a vmapped per-client objective  F_i + λ·KL(teacher‖student) + µ·prox
    mirroring ``distill.make_client_objective`` term for term.

Padding/mask contract
---------------------
Client shards have ragged sizes, so the engine stacks them once at
construction into dense ``(C, Bmax, …)`` arrays, ``Bmax = max_i n_i``:

  - ``qX``      (C, Bmax, n_qubits)  zero-padded features,
  - ``qy``      (C, Bmax)            zero-padded labels,
  - ``mask``    (C, Bmax)            1.0 on real rows, 0.0 on padding,
  - ``teacher`` (C, Bmax, n_classes) LLM soft labels, uniform on padding.

Every batch reduction is mask-weighted: NLL and KL average as
``Σ mask·term / Σ mask``, so padded rows are evaluated (dense shapes keep
XLA happy) but contribute exactly nothing — a padded client objective
equals its unpadded value.  Padded feature rows are all-zero, a valid
circuit input, so no NaNs leak through ``log``.

Per-client ``maxiter`` budgets become **iteration masks** (see
``batched_spsa`` / ``batched_nm``): the round always compiles to the same
shapes, budgets arrive as a traced ``(C,)`` array, and regulation never
recompiles.  The compiled round program is cached module-wide keyed by
the static config (which includes ``backend.shots`` — keyed sampling
changes the traced program), so fresh engine instances (new runs, tests,
benches) with the same task shape reuse it.

Shot-noise key contract
-----------------------
Finite-shot backends (``backend.shots > 0``) sample **inside** the fused
round program, per evaluation, under the ``backends.py`` derivation

    ``eval_key(PRNGKey(seed), round, client, slot)``

``run_round`` takes the orchestrator's 1-based round index and folds it
with each client id into a ``(C,)`` stack of per-client round keys
(traced inputs — no recompilation across rounds); the batched optimizers
fold in the structural evaluation ``slot``.  The sequential path derives
from the same chain (``orchestrator`` hands ``gradfree`` a per-client
``key_stream``), so on ``fake``/``aersim``/``real`` both engines use the
same key for the same evaluation — noisy parity is draw-for-draw, not
just in distribution.  (Identical keys make identical draws whenever the
two forwards agree on the sampled CDF; the tape and eager forwards
differ by ~2e-7 ulp noise, so a uniform draw landing inside that sliver
of a class boundary could in principle flip one shot — the parity tests
pin seeds where no draw does.)  With ``shots == 0`` the keys are inert
and the objective is the deterministic channel.

The sequential path remains the parity reference for both optimizers:
branch decisions, trajectories, and eval counts of the batched
Nelder–Mead match ``gradfree.nm_run`` decision-for-decision
(``tests/test_batched_nm.py`` / ``tests/test_batched_engine.py``).

Sharding-safety invariants (the 'clients' mesh axis)
----------------------------------------------------
With ``n_devices > 1`` the engine lays its ``(C, …)`` stacks across a
1-D ``'clients'`` device mesh (``distributed/sharding.py``) and lets the
jitted round program partition by computation-follows-data.  This is
safe because the round program preserves two invariants that sharding
relies on — keep them when editing this module or the batched
optimizers:

  1. **Per-client independence until aggregation.**  Nothing inside
     ``round_fn`` reduces, gathers, or permutes across the client axis;
     every op is elementwise or batched along ``C`` (the one exception,
     ``max(iters)`` for the shared loop bound, is a scalar all-reduce
     before the loop starts).  Each device therefore advances its slice
     of clients through the full NM/SPSA inner loop with zero
     cross-device collectives; the only cross-client mixing is the
     orchestrator's host-side weighted aggregation after ``run_round``
     returns.
  2. **Key folding is position-, not order-, dependent.**  Client
     ``c``'s round key is ``fold_in(fold_in(base, round), c)`` — a pure
     function of the client *id*, never of evaluation order or of which
     device holds the shard.  Sharding (or padding) the client axis
     must not renumber clients: real clients keep ids ``0..C-1`` and
     padding rows are appended after them, so every real client draws
     the same shots wherever it lands.

Ragged client counts are padded (``sharding.pad_client_count``) with
**inert** clients — all-zero masks, zero iteration budgets, uniform
teacher rows — and sliced off the outputs; the masked-mean denominator
is clamped to 1 so an all-padding client stays finite (bitwise inert
for real clients, whose mask sum is always >= 1).  With one device (or
``n_devices=None``) nothing is padded or placed and behavior is
identical to PR 1–3.

What "parity" means for the sharded round: the key draws are identical
by construction (invariant 2), and every client's program is the same
math — but XLA re-vectorizes within-client reductions for the
per-shard leading dim, which can shift noiseless f32 sums by
arithmetic-order noise (~2e-7, the same class as the documented
tape-vs-eager gap).  Paths that quantize — the NM branch ladder,
finite-shot sampling — absorb it, so sharded == single-device
**bitwise** at pinned seeds for Nelder–Mead and for ``shots > 0``
runs; noiseless SPSA (whose update consumes raw f differences) agrees
to ~1e-6 with identical draw/eval/branch accounting
(``tests/test_client_sharding.py`` pins each cell of that matrix).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as shd
from repro.optim.batched_nm import batched_nm, best_point
from repro.optim.batched_spsa import batched_spsa, make_deltas
from repro.quantum import tape as tape_mod

_ROUND_CACHE: Dict[tuple, object] = {}


def build_local_phase(spec, backend, *, lam: float, mu: float,
                      use_llm: bool, optimizer: str = "spsa",
                      max_iter: int = 100):
    """Traceable local-training phase — the round program's body.

    Returns ``local_phase(qX, qy, mask, teacher, theta_g, iters, ckeys,
    deltas=None, active=None) → (x (C, P) f32, n_evals (C,) int32)``,
    pure and jit-free: ``_build_round_fn`` wraps it in ``jax.jit`` for
    the per-round engine, and ``core/fused_rounds.py`` calls it inside
    its ``lax.scan`` body so the fused multi-round driver runs exactly
    the same math as the per-round program.

    ``deltas`` is required for SPSA (ignored by NM); ``active`` is the
    optional (C,) participation mask threaded to the batched optimizer
    (inactive clients keep ``theta_g`` and spend 0 evals; ``None`` is
    bitwise the all-active path).  ``ckeys`` is the (C,) per-client
    round-key stack (see the module's shot-noise key contract); inert
    when ``backend.shots == 0``.
    """
    cq = tape_mod.compile_qnn(spec)
    eps = 1e-9
    sampling = backend.shots > 0

    def client_objective(theta, Xc, yc, mc, tc, theta_g, ckey, slot):
        """F_i + λ·KL + µ·prox for ONE client on its padded shard."""
        probs = tape_mod.tape_probs(cq, theta, Xc)      # raw (B, cls)
        if sampling:
            noisy = backend.transform_probs(
                probs, jax.random.fold_in(ckey, slot))
        else:
            noisy = backend.apply_channel(probs)
        # clamp: all-padding clients (ragged C on a mesh) have Σmask = 0
        # and must stay finite; real clients have Σmask >= 1, for which
        # the maximum is bitwise inert
        m_sum = jnp.maximum(jnp.sum(mc), 1.0)
        p = jnp.take_along_axis(noisy, yc[:, None], axis=1)[:, 0]
        loss = -jnp.sum(jnp.log(p + eps) * mc) / m_sum  # masked NLL
        if use_llm and lam > 0:
            pt = jnp.clip(tc, eps, 1.0)                 # KL on raw probs
            ps = jnp.clip(probs, eps, 1.0)
            rows = jnp.sum(pt * (jnp.log(pt) - jnp.log(ps)), axis=-1)
            loss = loss + lam * jnp.sum(rows * mc) / m_sum
        if use_llm and mu > 0:
            loss = loss + mu * jnp.mean((theta - theta_g) ** 2)
        return loss

    vobj = jax.vmap(client_objective,
                    in_axes=(0, 0, 0, 0, 0, None, 0, None))

    def prep(qX, qy, mask, teacher, theta_g, ckeys):
        """Shared per-round start stack + closed-over objective.

        The objective is keyed (``f(xs, slot)``) iff the backend
        samples; the batched optimizers drive the slot schedule.
        """
        x0 = jnp.tile(theta_g[None, :], (qX.shape[0], 1))

        if sampling:
            def f(xs, slot):
                return vobj(xs, qX, qy, mask, teacher, theta_g,
                            ckeys, slot)
        else:
            def f(xs):
                return vobj(xs, qX, qy, mask, teacher, theta_g,
                            ckeys, jnp.int32(0))

        return x0, f

    if optimizer == "nelder-mead":
        def local_phase(qX, qy, mask, teacher, theta_g, iters, ckeys,
                        deltas=None, active=None):
            x0, f = prep(qX, qy, mask, teacher, theta_g, ckeys)
            simplex, fvals, n_evals, _ = batched_nm(f, x0, iters,
                                                    int(max_iter),
                                                    keyed=sampling,
                                                    active=active)
            x, _ = best_point(simplex, fvals)
            if active is not None:
                # an untouched init simplex's best vertex is an offset
                # row, not x0 — inactive clients must return their start
                x = jnp.where(active[:, None], x, x0)
            return x, n_evals
    elif optimizer == "spsa":
        def local_phase(qX, qy, mask, teacher, theta_g, iters, ckeys,
                        deltas=None, active=None):
            x0, f = prep(qX, qy, mask, teacher, theta_g, ckeys)
            x, _, n_evals = batched_spsa(f, x0, iters, deltas,
                                         keyed=sampling, active=active)
            if active is not None:
                x = jnp.where(active[:, None], x, x0)
            return x, n_evals
    else:
        raise ValueError(f"unknown batched optimizer {optimizer!r}")

    return local_phase


def _build_round_fn(spec, backend, lam: float, mu: float, use_llm: bool,
                    optimizer: str = "spsa", max_iter: int = 100):
    """Jitted per-round wrapper over ``build_local_phase`` →
    (x (C,P), n_evals (C,)).

    spsa        : (qX, qy, mask, teacher, θ_g, iters, deltas, ckeys)
    nelder-mead : (qX, qy, mask, teacher, θ_g, iters, ckeys) —
                  ``max_iter`` is a static bound (branch-record width),
                  budgets stay traced.
    """
    lp = build_local_phase(spec, backend, lam=lam, mu=mu, use_llm=use_llm,
                           optimizer=optimizer, max_iter=max_iter)
    if optimizer == "nelder-mead":
        @jax.jit
        def round_fn(qX, qy, mask, teacher, theta_g, iters, ckeys):
            return lp(qX, qy, mask, teacher, theta_g, iters, ckeys)
    else:
        @jax.jit
        def round_fn(qX, qy, mask, teacher, theta_g, iters, deltas, ckeys):
            return lp(qX, qy, mask, teacher, theta_g, iters, ckeys,
                      deltas=deltas)
    return round_fn


def get_round_fn(spec, backend, *, lam: float, mu: float, use_llm: bool,
                 optimizer: str = "spsa", max_iter: int = 100):
    # max_iter only shapes the NM branch record — keep SPSA keys stable.
    # backend (frozen dataclass) already hashes shots; the explicit
    # element documents that sampling is part of the program's identity.
    key = (spec, backend, int(backend.shots), float(lam), float(mu),
           bool(use_llm), optimizer,
           int(max_iter) if optimizer == "nelder-mead" else None)
    if key not in _ROUND_CACHE:
        _ROUND_CACHE[key] = _build_round_fn(spec, backend, lam, mu,
                                            use_llm, optimizer, max_iter)
    return _ROUND_CACHE[key]


class BatchedRoundEngine:
    """Stacks client data once; runs each round's local phase on device."""

    def __init__(self, task, spec, backend, *, lam: float, mu: float,
                 use_llm: bool, teacher_probs: Optional[List] = None,
                 seeds: Sequence[int] = (), max_iter: int = 100,
                 optimizer: str = "spsa", seed: int = 0,
                 n_devices: Optional[int] = None):
        C = task.n_clients
        n_cls = task.n_classes
        b_max = max(cl.n for cl in task.clients)

        # 'clients' mesh: shard the stacks' leading axis across devices
        # (see the module docstring's sharding-safety invariants); one
        # device (the default) skips padding and placement entirely.
        self._mesh = None
        c_pad = C
        if n_devices is not None and int(n_devices) > 1:
            self._mesh = shd.client_mesh(int(n_devices))
            c_pad = shd.pad_client_count(C, int(n_devices))

        qX = np.zeros((c_pad, b_max, spec.n_qubits), np.float32)
        qy = np.zeros((c_pad, b_max), np.int32)
        mask = np.zeros((c_pad, b_max), np.float32)
        teacher = np.full((c_pad, b_max, n_cls), 1.0 / n_cls, np.float32)
        for i, cl in enumerate(task.clients):
            qX[i, :cl.n] = cl.qX
            qy[i, :cl.n] = cl.qy
            mask[i, :cl.n] = 1.0
            if teacher_probs is not None and teacher_probs[i] is not None:
                teacher[i, :cl.n] = np.asarray(teacher_probs[i],
                                               np.float32)
        self._qX, self._qy = jnp.asarray(qX), jnp.asarray(qy)
        self._mask, self._teacher = jnp.asarray(mask), jnp.asarray(teacher)
        self._optimizer = optimizer
        if optimizer == "spsa":
            # padding clients never update (zero budgets) but their delta
            # rows are still indexed every masked iteration — keep them
            # valid Rademacher signs, not zeros (0 ⇒ 1/δ = inf)
            deltas = np.ones((c_pad, max_iter, spec.n_params), np.float64)
            deltas[:C] = make_deltas(seeds, max_iter, spec.n_params)
            self._deltas = jnp.asarray(deltas, jnp.float32)
        else:
            self._deltas = None        # NM is deterministic — no draws
        # sequential-path evals spent before the metered run: spsa_init
        # does 1, nm_init does n+1 (the initial simplex)
        self.init_evals = 1 if optimizer == "spsa" else spec.n_params + 1
        # shot-noise key chain root: fold_in(round)/fold_in(client) happen
        # per run_round, fold_in(slot) inside the optimizers
        self._base_key = jax.random.PRNGKey(seed)
        self._n_clients = C
        self._c_pad = c_pad
        if self._mesh is not None:
            stacks = (self._qX, self._qy, self._mask, self._teacher)
            if self._deltas is not None:
                stacks = stacks + (self._deltas,)
            placed = shd.put_client_stacks(self._mesh, stacks, c_pad)
            (self._qX, self._qy, self._mask, self._teacher,
             *rest) = placed
            if rest:
                self._deltas = rest[0]
        self._round = get_round_fn(spec, backend, lam=lam, mu=mu,
                                   use_llm=use_llm, optimizer=optimizer,
                                   max_iter=max_iter)

    def run_round(self, theta_g: np.ndarray, maxiters: Sequence[int],
                  round_idx: int = 0) -> Tuple[np.ndarray, np.ndarray]:
        """One local-training phase for all clients.

        ``round_idx`` is the orchestrator's 1-based round counter — the
        ``round`` stage of the key-derivation contract.  Returns
        (thetas (C, P) float64, n_evals (C,) int) — the trained
        per-client parameters and the sequential-equivalent evaluation
        counts (``init_evals`` + the metered run's branch-dependent spend)
        for comm accounting.

        On a client mesh the per-round inputs are placed like the
        stacks (budgets/keys along 'clients', θ_g replicated) and the
        padding rows — zero budgets, key ids ``C..c_pad-1`` that fold
        *after* every real client's id — are sliced off the outputs.
        """
        rk = jax.random.fold_in(self._base_key, round_idx)
        ckeys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
            rk, jnp.arange(self._c_pad))
        iters = np.zeros((self._c_pad,), np.int32)
        iters[:self._n_clients] = np.asarray(maxiters, np.int32)
        theta_g = jnp.asarray(theta_g, jnp.float32)
        iters = jnp.asarray(iters)
        if self._mesh is not None:
            # θ_g is replicated explicitly: its leading dim (n_params)
            # must never be mistaken for a client axis by shape inference
            theta_g = shd.put_replicated(self._mesh, theta_g)
            iters, ckeys = shd.put_client_stacks(
                self._mesh, (iters, ckeys), self._c_pad)
        args = [self._qX, self._qy, self._mask, self._teacher,
                theta_g, iters]
        if self._optimizer == "spsa":
            args.append(self._deltas)
        args.append(ckeys)
        x, n_evals = self._round(*args)
        C = self._n_clients
        return (np.asarray(x, np.float64)[:C],
                np.asarray(n_evals, np.int64)[:C])
