"""Early-termination criterion (Sec. III-B):
stop when ΔL_s^t / L_s^t < ε or t ≥ T_max."""
from __future__ import annotations

from typing import List, Optional


class TerminationCriterion:
    def __init__(self, *, epsilon: float = 1e-3, t_max: int = 100,
                 patience: int = 1):
        self.epsilon = epsilon
        self.t_max = t_max
        self.patience = patience          # consecutive small-improvements
        self._history: List[float] = []
        self._small = 0

    def update(self, server_loss: float, t: int) -> bool:
        """Record round-t server loss; True → stop."""
        h = self._history
        h.append(float(server_loss))
        if t >= self.t_max:
            return True
        if len(h) >= 2:
            if abs(h[-1]) > 0:
                rel = abs(h[-1] - h[-2]) / abs(h[-1])
            else:
                # loss hit exactly 0: a zero-loss plateau (Δ = 0) is
                # converged; a fresh drop to 0 still counts as progress
                rel = 0.0 if h[-2] == h[-1] else float("inf")
            self._small = self._small + 1 if rel < self.epsilon else 0
            if self._small >= self.patience:
                return True
        return False

    @property
    def history(self) -> List[float]:
        return list(self._history)
