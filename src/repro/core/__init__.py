"""LLM-QFL core — the paper's contribution (Alg. 1 + Sec. III).

Public API:
    RunConfig, Orchestrator, run_experiment   — the federated loop
    regulation.regulate                        — optimizer regulation law
    selection.select_aligned                   — alignment client selection
    termination.TerminationCriterion           — early stopping
    distill.kl_divergence / make_client_objective
    llm_client.LLMClient                       — per-client LLM fine-tuning
                                                 (sequential parity reference)
    batched_llm.BatchedLLMEngine               — the fine-tuning stage as one
                                                 jitted, mesh-shardable program
"""
from repro.core import (batched_llm, distill, llm_client, regulation,  # noqa: F401
                        selection, termination)
from repro.core.orchestrator import Orchestrator, RunConfig, RunResult, run_experiment  # noqa: F401
