"""PCA dimensionality reduction (App. B.3 step 4): 800-dim one-hot genomic
features → n_components=4 → scaled to [0, π] for 4-qubit angle encoding."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class PCA:
    components: np.ndarray        # (d, k)
    mean: np.ndarray              # (d,)
    lo: np.ndarray = None         # per-dim min (for [0,π] rescale)
    hi: np.ndarray = None

    def transform(self, X: np.ndarray) -> np.ndarray:
        Z = (X - self.mean) @ self.components
        if self.lo is not None:
            Z = (Z - self.lo) / np.maximum(self.hi - self.lo, 1e-9)
            Z = np.clip(Z, 0.0, 1.0) * np.pi
        return Z.astype(np.float32)


def fit(X: np.ndarray, n_components: int = 4, *, scale_to_pi: bool = True
        ) -> PCA:
    mean = X.mean(axis=0)
    Xc = X - mean
    # economy SVD — d can be 800, n in the tens of thousands
    _, _, vt = np.linalg.svd(Xc, full_matrices=False)
    comp = vt[:n_components].T
    p = PCA(comp, mean)
    if scale_to_pi:
        Z = Xc @ comp
        p.lo, p.hi = Z.min(axis=0), Z.max(axis=0)
    return p
