"""Synthetic TweetEval-sentiment generator (DESIGN.md §2).

Real dataset: 45,615 train / 12,284 test / 2,000 val tweets, 3 classes
(negative=0, neutral=1, positive=2).  Surrogate: class-conditional unigram
mixtures over a small word vocabulary — sentiment-bearing words are drawn
with class-dependent rates, fillers uniformly, lengths ~ N(18, 6) words.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

N_CLASSES = 3

_POS = ["love", "great", "happy", "awesome", "best", "amazing", "win",
        "beautiful", "fun", "excited"]
_NEG = ["hate", "terrible", "sad", "awful", "worst", "angry", "lose",
        "ugly", "boring", "disappointed"]
_NEU = ["today", "meeting", "report", "weather", "schedule", "update",
        "news", "city", "game", "event"]
_FILL = ["the", "a", "is", "was", "to", "and", "of", "in", "it", "that",
         "this", "on", "for", "with", "at", "user", "rt", "qt", "so",
         "very", "just", "now", "then", "here", "there"]

VOCAB: List[str] = sorted(set(_POS + _NEG + _NEU + _FILL))
WORD_ID = {w: i for i, w in enumerate(VOCAB)}

# class → (sentiment-lexicon, rate of sentiment words)
_CLASS_LEX = {0: (_NEG, 0.35), 1: (_NEU, 0.30), 2: (_POS, 0.35)}


def generate(n: int, *, seed: int = 0) -> Tuple[List[str], np.ndarray]:
    """Returns (texts, labels (n,) int32)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, N_CLASSES, size=n).astype(np.int32)
    texts = []
    for y in labels:
        lex, rate = _CLASS_LEX[int(y)]
        length = max(4, int(rng.normal(18, 6)))
        words = []
        for _ in range(length):
            if rng.random() < rate:
                words.append(lex[rng.integers(0, len(lex))])
            else:
                words.append(_FILL[rng.integers(0, len(_FILL))])
        texts.append(" ".join(words))
    return texts, labels


def bag_features(texts: List[str], n_features: int = 4) -> np.ndarray:
    """Sentiment-score features for the 4-qubit QNN encoding: per text,
    [pos_rate, neg_rate, neu_rate, log-length], scaled to [0, π] later."""
    pos, neg, neu = set(_POS), set(_NEG), set(_NEU)
    out = np.zeros((len(texts), 4), np.float32)
    for i, t in enumerate(texts):
        ws = t.split()
        L = max(len(ws), 1)
        out[i, 0] = sum(w in pos for w in ws) / L
        out[i, 1] = sum(w in neg for w in ws) / L
        out[i, 2] = sum(w in neu for w in ws) / L
        out[i, 3] = np.log1p(L) / 4.0
    return out[:, :n_features]
