"""Task assembly: dataset → (quantum features, LLM token batches) per client.

Experiment I  (paper Sec. IV): genomic + VQC + LLaMA-3.2-1B-LoRA.
Experiment II (paper Sec. IV): tweets  + QCNN + GPT-2 / DeepSeek-7B.

``build_task`` returns a ``FederatedTask`` holding per-client shards in both
representations, plus held-out test/val splits — everything ``repro.core``
needs to run Algorithm 1.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.data import federated, genomic, pca as pca_mod, tokenizer, tweets


@dataclass
class ClientShard:
    qX: np.ndarray               # (n_i, n_features) angle feats in [0, π]
    qy: np.ndarray               # (n_i,)
    llm_batch: Dict[str, np.ndarray]     # tokens/labels for LoRA fine-tune
    n: int = 0

    def __post_init__(self):
        self.n = len(self.qy)


@dataclass
class FederatedTask:
    name: str                    # "genomic" | "tweets"
    n_classes: int
    clients: List[ClientShard]
    test_qX: np.ndarray
    test_qy: np.ndarray
    val_qX: np.ndarray
    val_qy: np.ndarray
    vocab_size: int
    llm_seq_len: int
    weights: np.ndarray = field(default=None)

    @property
    def n_clients(self) -> int:
        return len(self.clients)


def build_task(name: str, *, n_clients: int = 5, train_size: int = 1000,
               test_size: int = 200, val_size: int = 100,
               non_iid_alpha: float = 0.0, seed: int = 0,
               llm_seq_len: int = 64, n_features: int = 4) -> FederatedTask:
    if name == "genomic":
        seqs, labels = genomic.generate(train_size + test_size + val_size,
                                        seed=seed)
        feats = genomic.one_hot(seqs)
        texts = genomic.to_text(seqs)
        tok = tokenizer.KmerTokenizer(k=6, n_labels=2)
        token_lists = [tok.encode(t) for t in texts]
        n_classes = 2
    elif name == "tweets":
        texts, labels = tweets.generate(train_size + test_size + val_size,
                                        seed=seed)
        feats = tweets.bag_features(texts, n_features=n_features)
        tok = tokenizer.WordTokenizer(tweets.VOCAB, n_labels=3)
        token_lists = [tok.encode(t) for t in texts]
        n_classes = 3
    else:
        raise ValueError(name)

    tr = slice(0, train_size)
    te = slice(train_size, train_size + test_size)
    va = slice(train_size + test_size, train_size + test_size + val_size)

    # PCA(n_features) fit on train only, angle-scaled to [0, π];
    # n_features = n_qubits of the QNN that will consume the task
    p = pca_mod.fit(feats[tr], n_components=n_features)
    qX = p.transform(feats)
    if qX.shape[1] != n_features:
        # bag_features caps at its lexicon scores; PCA caps at data rank
        raise ValueError(
            f"task {name!r} can only encode {qX.shape[1]} features "
            f"(requested n_features={n_features})")

    if non_iid_alpha > 0:
        shards = federated.split_dirichlet(labels[tr], n_clients,
                                           alpha=non_iid_alpha, seed=seed)
    else:
        shards = federated.split_iid(train_size, n_clients, seed=seed)

    packed = tokenizer.pack_classification(token_lists, labels, tok,
                                           max_len=llm_seq_len)
    clients = []
    for idx in shards:
        clients.append(ClientShard(
            qX=qX[tr][idx], qy=labels[tr][idx],
            llm_batch={"tokens": packed["tokens"][tr][idx],
                       "labels": packed["labels"][tr][idx]}))

    task = FederatedTask(
        name=name, n_classes=n_classes, clients=clients,
        test_qX=qX[te], test_qy=labels[te],
        val_qX=qX[va], val_qy=labels[va],
        vocab_size=tok.vocab_size, llm_seq_len=llm_seq_len)
    task.weights = federated.client_weights(shards)
    return task
