"""Synthetic DemoHumanOrWorm generator (DESIGN.md §2).

The real dataset (genomic-benchmarks, 75k train / 25k test) is a binary
classification of 200-nucleotide sequences: Human (0) vs Worm (1).  Offline
we generate a *learnable* surrogate with the same shapes/cardinalities:
class-conditional base composition (human ~41% GC, worm ~36% GC) plus
class-specific planted motifs at random offsets — recoverable by both the
k-mer LLM path and the PCA→4-qubit quantum path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

NUCLEOTIDES = "ACGT"
NUCLEOTIDE_MAP = {"A": 0, "C": 1, "G": 2, "T": 3}   # paper Sec. IV Exp. I
SEQ_LEN = 200

# class-specific motifs (planted signal)
_MOTIFS = {0: ["TATAAA", "GGCCGG", "CCGCCC"],        # human-like
           1: ["TTGATA", "AATTTT", "GATAAG"]}        # worm-like
_GC = {0: 0.41, 1: 0.36}


def generate(n: int, *, seed: int = 0, motif_rate: float = 0.9
             ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (sequences (n, 200) int8 in {0..3}, labels (n,) int32)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 2, size=n).astype(np.int32)
    seqs = np.empty((n, SEQ_LEN), np.int8)
    for cls in (0, 1):
        idx = np.where(labels == cls)[0]
        gc = _GC[cls]
        # base distribution over A,C,G,T
        p = np.array([(1 - gc) / 2, gc / 2, gc / 2, (1 - gc) / 2])
        seqs[idx] = rng.choice(4, size=(len(idx), SEQ_LEN), p=p)
        # plant motifs
        for i in idx:
            if rng.random() < motif_rate:
                for m in _MOTIFS[cls]:
                    if rng.random() < 0.7:
                        enc = np.array([NUCLEOTIDE_MAP[c] for c in m],
                                       np.int8)
                        off = rng.integers(0, SEQ_LEN - len(enc))
                        seqs[i, off:off + len(enc)] = enc
    return seqs, labels


def one_hot(seqs: np.ndarray) -> np.ndarray:
    """(n, 200) int → (n, 800) float32 one-hot (A=[1,0,0,0], ... App. B.3)."""
    n, L = seqs.shape
    out = np.zeros((n, L, 4), np.float32)
    out[np.arange(n)[:, None], np.arange(L)[None, :], seqs] = 1.0
    return out.reshape(n, L * 4)


def to_text(seqs: np.ndarray) -> list:
    """int sequences → 'ACGT' strings (LLM tokenization input)."""
    lut = np.array(list(NUCLEOTIDES))
    return ["".join(lut[s]) for s in seqs]
