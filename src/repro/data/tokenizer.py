"""Tokenizers for the LLM fine-tuning path.

 - ``KmerTokenizer`` : k-mer (k=6 default) tokenization of nucleotide
   strings — the paper's genomic preprocessing (App. B.3 step 3).
 - ``WordTokenizer`` : whitespace word-level tokenizer for tweets.

Both reserve ids: 0=PAD, 1=BOS, 2=EOS, 3=UNK, and a contiguous block of
**label tokens** at the top of the vocab so classification is cast as
next-token prediction (the causal-LM-native form of "sequence
classification with 2 labels").
"""
from __future__ import annotations

import itertools
from typing import Iterable, List, Sequence

import numpy as np

PAD, BOS, EOS, UNK = 0, 1, 2, 3
_SPECIALS = 4


class KmerTokenizer:
    def __init__(self, k: int = 6, n_labels: int = 2, stride: int = None):
        self.k = k
        self.stride = stride or k
        self.n_labels = n_labels
        # full 4^k k-mer vocab (4096 for k=6), deterministic order
        kmers = ["".join(p) for p in itertools.product("ACGT", repeat=k)]
        self._kmer_id = {m: _SPECIALS + i for i, m in enumerate(kmers)}
        self.vocab_size = _SPECIALS + len(kmers) + n_labels

    def label_token(self, label: int) -> int:
        return self.vocab_size - self.n_labels + int(label)

    def encode(self, seq: str) -> List[int]:
        ids = [BOS]
        for i in range(0, len(seq) - self.k + 1, self.stride):
            ids.append(self._kmer_id.get(seq[i:i + self.k], UNK))
        return ids


class WordTokenizer:
    def __init__(self, vocab: Sequence[str], n_labels: int = 3):
        self.n_labels = n_labels
        self._word_id = {w: _SPECIALS + i for i, w in enumerate(vocab)}
        self.vocab_size = _SPECIALS + len(vocab) + n_labels

    def label_token(self, label: int) -> int:
        return self.vocab_size - self.n_labels + int(label)

    def encode(self, text: str) -> List[int]:
        return [BOS] + [self._word_id.get(w, UNK) for w in text.split()]


def pack_classification(token_lists: Iterable[List[int]],
                        labels: np.ndarray, tok, max_len: int
                        ) -> dict:
    """Build (tokens, labels) arrays for causal-LM classification:
    sequence + label-token appended; CE mask everywhere except the label
    position (labels=-1 masked by ``chunked_ce``)."""
    labels = np.asarray(labels)
    n = len(labels)
    toks = np.full((n, max_len), PAD, np.int32)
    ys = np.full((n, max_len), -1, np.int32)
    for i, ids in enumerate(token_lists):
        ids = list(ids)[: max_len - 1]
        toks[i, : len(ids)] = ids
        # the model must predict the label token after the sequence
        ys[i, len(ids) - 1] = tok.label_token(int(labels[i]))
        if len(ids) < max_len:          # teacher-forced label position
            toks[i, len(ids)] = tok.label_token(int(labels[i]))
    return {"tokens": toks, "labels": ys}
