from repro.data import federated, genomic, pca, tasks, tokenizer, tweets  # noqa: F401
