"""Federated data partitioning: IID and Dirichlet non-IID client splits."""
from __future__ import annotations

from typing import Dict, List

import numpy as np


def split_iid(n: int, n_clients: int, *, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return [np.sort(s) for s in np.array_split(perm, n_clients)]


def split_dirichlet(labels: np.ndarray, n_clients: int, *,
                    alpha: float = 0.5, seed: int = 0,
                    min_per_client: int = 8) -> List[np.ndarray]:
    """Label-skew non-IID partition: per class, proportions ~ Dir(alpha)."""
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    classes = np.unique(labels)
    shards: List[List[int]] = [[] for _ in range(n_clients)]
    for c in classes:
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for shard, part in zip(shards, np.split(idx, cuts)):
            shard.extend(part.tolist())
    # rebalance clients that got starved
    sizes = np.array([len(s) for s in shards])
    while sizes.min() < min_per_client:
        src, dst = int(np.argmax(sizes)), int(np.argmin(sizes))
        shards[dst].append(shards[src].pop())
        sizes = np.array([len(s) for s in shards])
    return [np.sort(np.array(s)) for s in shards]


def client_weights(shards: List[np.ndarray]) -> np.ndarray:
    """w_i = |D_i| / |D| (Eq. 2)."""
    sizes = np.array([len(s) for s in shards], np.float64)
    return sizes / sizes.sum()
